"""Contracts for the pluggable transport subsystem (``repro.core.transport``).

Four layers of guarantees, mirroring ARCHITECTURE.md §Transport:

* **Registry contract** — transports resolve by string key exactly like
  algorithms/topologies/backends; ``"none"`` maps to no policy object at
  all (the hot path carries zero transport overhead by default), unknown
  names fail loudly with the valid set.
* **Goldens-unaffected guarantee** — every golden scenario replays
  bit-for-bit with ``transport="none"`` spelled out explicitly.
* **Go-back-N exactness** — with ``transport="gbn"`` every algorithm's
  reduction is exact under packet loss, on both fabrics (property-tested
  across algo x drop_prob x seed).
* **DCQCN observability** — a congested run produces ECN marks, CNPs, rate
  cuts and PFC pauses in ``SimResult.transport_stats``; throttled hosts
  surface in ``host_rate_gbps``; per-cause drop counters reconcile with the
  global drop total; everything is deterministic per seed.
"""
import dataclasses

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover - CI always has hypothesis
    HAVE_HYP = False

from golden_cases import CASES, load_goldens, result_to_jsonable
from repro.core.canary import (Algo, AllreduceJob, SimConfig, Simulator,
                               scaled_config, three_tier_config)
from repro.core.transport import TRANSPORTS, make_transport, \
    register_transport
from repro.core.transport.base import TransportPolicy


def _run(cfg, algo=Algo.CANARY, n_hosts=8, data_bytes=32768, noise=None):
    jobs = [AllreduceJob(0, list(range(n_hosts)), data_bytes)]
    sim = Simulator(cfg, jobs, algo=algo, noise_hosts=noise)
    return sim.run()


# --------------------------------------------------------------------------
# Registry contract
# --------------------------------------------------------------------------
def test_registry_has_builtin_policies():
    assert set(TRANSPORTS) >= {"gbn", "dcqcn"}
    assert "none" not in TRANSPORTS  # "none" is the absence of a policy


def test_make_transport_none_returns_no_policy():
    assert make_transport("none", sim=None) is None


def test_make_transport_unknown_name_lists_valid_set():
    with pytest.raises(ValueError) as ei:
        make_transport("quic", sim=None)
    msg = str(ei.value)
    assert "quic" in msg
    for name in ("none", "gbn", "dcqcn"):
        assert name in msg


def test_register_transport_decorator_round_trips():
    @register_transport("test_noop")
    class _Noop(TransportPolicy):
        name = "test_noop"

    try:
        assert TRANSPORTS["test_noop"] is _Noop
        cfg = SimConfig(num_leaves=2, hosts_per_leaf=2, num_spines=2,
                        table_size=64, transport="test_noop")
        res = _run(cfg, n_hosts=4, data_bytes=8192)
        assert res.correct and res.transport == "test_noop"
    finally:
        del TRANSPORTS["test_noop"]


def test_simulator_rejects_unknown_transport():
    cfg = SimConfig(num_leaves=2, hosts_per_leaf=2, num_spines=2,
                    table_size=64, transport="quic")
    with pytest.raises(ValueError, match="quic"):
        Simulator(cfg, [AllreduceJob(0, [0, 1, 2, 3], 8192)],
                  algo=Algo.CANARY)


# --------------------------------------------------------------------------
# Goldens-unaffected guarantee
# --------------------------------------------------------------------------
def test_goldens_bit_identical_under_explicit_none():
    """All 15 goldens with transport="none" spelled out — the default path
    and the explicit path must be the same path."""
    import golden_cases
    goldens = load_goldens()
    for name in sorted(CASES):
        cfg_kw, jobs_spec, algo, n_trees, noise = CASES[name]
        cfg = dataclasses.replace(golden_cases._cfg(**cfg_kw),
                                  transport="none")
        sim = Simulator(cfg, golden_cases._jobs(jobs_spec), algo=algo,
                        n_trees=n_trees, noise_hosts=noise)
        assert sim.transport is None, "no policy object on the default path"
        got = result_to_jsonable(sim.run())
        assert got == goldens[name], \
            f"golden {name!r} diverged under transport='none'"


# --------------------------------------------------------------------------
# Go-back-N exactness under loss
# --------------------------------------------------------------------------
def _lossy_cfg(topology, drop, seed=5, **kw):
    base = dict(drop_prob=drop, retx_timeout_ns=5e4, seed=seed,
                transport="gbn", max_events=30_000_000)
    base.update(kw)
    if topology == "three_tier":
        return three_tier_config(**base)
    return scaled_config(4, **base)


@pytest.mark.parametrize("topology", ["fat_tree", "three_tier"])
@pytest.mark.parametrize("algo", [Algo.CANARY, Algo.STATIC_TREE, Algo.RING])
def test_gbn_exact_under_loss_both_fabrics(topology, algo):
    res = _run(_lossy_cfg(topology, 0.01), algo=algo, data_bytes=65536)
    assert res.correct, f"{algo} inexact under loss with gbn on {topology}"
    assert res.dropped_packets > 0, "cell must actually exercise loss"
    assert res.transport == "gbn"


def test_gbn_ring_recovers_via_sequence_numbers():
    """RING runs on raw unicast flows — recovery must come from the gbn
    machinery itself (ACKs, timer retransmits, in-order delivery), not from
    the leader FAIL protocol (ring has none)."""
    res = _run(_lossy_cfg("fat_tree", 0.02), algo=Algo.RING,
               data_bytes=65536)
    ts = res.transport_stats
    assert res.correct
    assert ts["gbn_acks"] > 0
    assert ts["gbn_retx"] > 0, "drops at 2% must trigger gbn retransmits"
    assert res.drop_causes["gbn_ooo_discard"] == ts["gbn_ooo"]


def test_gbn_exact_with_noise_and_loss():
    cfg = _lossy_cfg("fat_tree", 0.01)
    res = _run(cfg, algo=Algo.CANARY, n_hosts=8, data_bytes=65536,
               noise=list(range(8, 16)))
    assert res.correct and res.dropped_packets > 0


def _assert_gbn_exact(algo, drop, seed):
    res = _run(_lossy_cfg("fat_tree", drop, seed=seed), algo=algo,
               data_bytes=32768)
    assert res.correct, (f"inexact: algo={algo} drop={drop} seed={seed} "
                         f"retx={res.retransmissions}")


@pytest.mark.parametrize("algo", [Algo.CANARY, Algo.STATIC_TREE, Algo.RING])
@pytest.mark.parametrize("drop,seed", [(0.005, 1), (0.02, 9)])
def test_gbn_reduction_exact_pinned_grid(algo, drop, seed):
    """The acceptance property on a pinned sample: any algorithm, any loss
    rate, any seed — the reduction is exact once go-back-N is on."""
    _assert_gbn_exact(algo, drop, seed)


if HAVE_HYP:
    @settings(max_examples=15, deadline=None)
    @given(algo=st.sampled_from([Algo.CANARY, Algo.STATIC_TREE, Algo.RING]),
           drop=st.sampled_from([0.002, 0.005, 0.01, 0.02]),
           seed=st.integers(min_value=0, max_value=2 ** 16))
    def test_gbn_reduction_exact_property(algo, drop, seed):
        """Hypothesis widens the pinned grid across the full seed space."""
        _assert_gbn_exact(algo, drop, seed)


def test_gbn_determinism():
    a = result_to_jsonable(_run(_lossy_cfg("fat_tree", 0.01), Algo.RING,
                                data_bytes=65536))
    b = result_to_jsonable(_run(_lossy_cfg("fat_tree", 0.01), Algo.RING,
                                data_bytes=65536))
    assert a == b


# --------------------------------------------------------------------------
# DCQCN observability
# --------------------------------------------------------------------------
def _congested_dcqcn(algo=Algo.CANARY, **kw):
    base = dict(seed=13, transport="dcqcn", noise_prob=0.9,
                noise_delay_ns=100.0)
    base.update(kw)
    cfg = scaled_config(4, **base)
    return _run(cfg, algo=algo, n_hosts=8, data_bytes=131072,
                noise=list(range(8, cfg.num_hosts)))


def test_dcqcn_marks_cnps_and_rate_cuts_under_congestion():
    res = _congested_dcqcn()
    ts = res.transport_stats
    assert res.correct
    assert ts["ecn_marks"] > 0, "congested egress queues must RED-mark"
    assert ts["cnps"] > 0, "marked deliveries must echo CNPs"
    assert ts["rate_cuts"] > 0, "CNPs must cut sender rates"


def test_dcqcn_throttles_hosts_below_line_rate():
    res = _congested_dcqcn()
    assert res.host_rate_gbps, "rate-limited hosts must surface telemetry"
    line_gbps = scaled_config(4).link_gbps
    for host, rate in res.host_rate_gbps.items():
        assert 0 < rate < line_gbps


def test_dcqcn_pfc_pauses_fire_and_resolve():
    res = _congested_dcqcn(pfc_pause_bytes=8192, pfc_resume_bytes=4096)
    ts = res.transport_stats
    assert res.correct
    assert ts["pfc_pauses"] > 0
    assert ts["pfc_pause_ns"] > 0
    # paused time is bounded by the run: every pause eventually resumed
    assert ts["pfc_pause_ns"] < res.duration_ns * res.transport_stats.get(
        "pfc_pauses", 1)


def test_dcqcn_exact_on_three_tier():
    cfg = three_tier_config(seed=13, transport="dcqcn", noise_prob=0.9,
                            noise_delay_ns=100.0)
    res = _run(cfg, algo=Algo.STATIC_TREE, n_hosts=8, data_bytes=65536,
               noise=list(range(8, cfg.num_hosts)))
    assert res.correct
    assert res.transport_stats["ecn_marks"] > 0


def test_dcqcn_determinism():
    a = result_to_jsonable(_congested_dcqcn())
    b = result_to_jsonable(_congested_dcqcn())
    assert a == b


# --------------------------------------------------------------------------
# Telemetry plumbing (per-cause drops, summary lines)
# --------------------------------------------------------------------------
def test_drop_causes_reconcile_with_global_counter():
    res = _run(_lossy_cfg("fat_tree", 0.01), algo=Algo.RING,
               data_bytes=65536)
    dc = res.drop_causes
    assert dc["wire"] + dc["switch_fail"] == res.dropped_packets
    assert dc["switch_fail"] == 0


def test_drop_causes_attribute_switch_failures():
    cfg = scaled_config(4, switch_fail_ns=2000.0, failed_switch=5,
                        retx_timeout_ns=5e4, seed=3)
    res = _run(cfg, algo=Algo.CANARY, n_hosts=10, data_bytes=32768)
    dc = res.drop_causes
    assert res.correct
    assert dc["switch_fail"] > 0, "failed-switch sinks must be attributed"
    assert dc["wire"] + dc["switch_fail"] == res.dropped_packets


def test_summary_carries_drop_causes_and_transport_counters():
    res = _congested_dcqcn()
    s = res.summary()
    assert "drops[wire=" in s and "switch_fail=" in s
    assert "tp=dcqcn[" in s and "ecn=" in s and "cnp=" in s
    none_s = _run(scaled_config(4), n_hosts=8).summary()
    assert "tp=" not in none_s, "default path stays free of transport noise"
