"""Golden-replay scenarios for the simulator.

Each case pins a full ``SimConfig`` + job set + algorithm. The goldens under
``goldens/simulator_goldens.json`` were captured from the pre-refactor
monolithic ``Simulator`` (PR 1); the layered engine must reproduce every
``SimResult`` field **bit-identically** — same event count, same completion
times, same counters — on every case. Any diff means the refactor changed
behaviour, not just structure.

Regenerate (only when a behaviour change is intentional and understood) with::

    PYTHONPATH=src python tests/core/capture_goldens.py
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List

from repro.core.canary import Algo, AllreduceJob, SimConfig, Simulator

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "goldens",
                           "simulator_goldens.json")


def _cfg(**kw) -> SimConfig:
    base = dict(num_leaves=4, hosts_per_leaf=4, num_spines=4,
                table_size=4096, seed=11, max_events=20_000_000)
    base.update(kw)
    return SimConfig(**base)


def _jobs(spec: List[dict]) -> List[AllreduceJob]:
    return [AllreduceJob(**s) for s in spec]


# name -> (cfg kwargs, job specs, algo, n_trees, noise hosts)
CASES: Dict[str, tuple] = {
    "canary_basic": (
        dict(), [dict(app=0, participants=list(range(8)), data_bytes=32768)],
        Algo.CANARY, 1, None),
    "canary_spread_leaves": (
        dict(seed=7), [dict(app=0, participants=[0, 4, 8, 12, 13, 15],
                            data_bytes=65536)],
        Algo.CANARY, 1, None),
    "canary_collisions": (
        dict(table_size=1),
        [dict(app=0, participants=list(range(8)), data_bytes=16384)],
        Algo.CANARY, 1, None),
    "canary_drops": (
        dict(drop_prob=0.01, retx_timeout_ns=5e4, seed=5),
        [dict(app=0, participants=list(range(8)), data_bytes=16384)],
        Algo.CANARY, 1, None),
    "canary_switch_failure": (
        dict(switch_fail_ns=2000.0, failed_switch=5, retx_timeout_ns=5e4,
             seed=3),
        [dict(app=0, participants=list(range(10)), data_bytes=32768)],
        Algo.CANARY, 1, None),
    "canary_congestion_noise": (
        dict(noise_prob=0.05, noise_delay_ns=1000.0, seed=13),
        [dict(app=0, participants=list(range(8)), data_bytes=32768)],
        Algo.CANARY, 1, list(range(8, 16))),
    "canary_multiapp_partitioned": (
        dict(table_size=8192, partition_table=True),
        [dict(app=0, participants=[0, 1, 2, 3], data_bytes=8192),
         dict(app=1, participants=[4, 5, 6, 7], data_bytes=8192)],
        Algo.CANARY, 1, None),
    "canary_mixed_collectives": (
        dict(table_size=8192, seed=2),
        [dict(app=0, participants=[0, 1, 2, 3], data_bytes=16384),
         dict(app=1, participants=[4, 5, 6, 7], data_bytes=16384,
              collective="reduce", root=4),
         dict(app=2, participants=[8, 9, 10, 11], data_bytes=16384,
              collective="broadcast", root=8),
         dict(app=3, participants=[12, 13, 14, 15], data_bytes=0,
              collective="barrier")],
        Algo.CANARY, 1, None),
    "canary_tiny_timeout": (
        dict(timeout_ns=50.0),
        [dict(app=0, participants=list(range(12)), data_bytes=65536)],
        Algo.CANARY, 1, None),
    "static_single_tree": (
        dict(), [dict(app=0, participants=list(range(16)), data_bytes=16384)],
        Algo.STATIC_TREE, 1, None),
    "static_four_trees_noise": (
        dict(seed=17), [dict(app=0, participants=list(range(8)),
                             data_bytes=32768)],
        Algo.STATIC_TREE, 4, list(range(8, 16))),
    "ring_basic": (
        dict(), [dict(app=0, participants=[0, 1, 2, 5, 9, 10, 14],
                      data_bytes=10000)],
        Algo.RING, 1, None),
    "ring_noise": (
        dict(seed=23), [dict(app=0, participants=list(range(8)),
                             data_bytes=32768)],
        Algo.RING, 1, list(range(8, 16))),
    "ecmp_lb": (
        dict(seed=29, lb="ecmp"),
        [dict(app=0, participants=list(range(8)), data_bytes=32768)],
        Algo.CANARY, 1, list(range(8, 16))),
    "per_packet_lb": (
        dict(seed=31, lb="per_packet"),
        [dict(app=0, participants=list(range(8)), data_bytes=32768)],
        Algo.CANARY, 1, list(range(8, 16))),
}


def build_simulator(name: str) -> Simulator:
    cfg_kw, jobs_spec, algo, n_trees, noise = CASES[name]
    return Simulator(_cfg(**cfg_kw), _jobs(jobs_spec), algo=algo,
                     n_trees=n_trees, noise_hosts=noise)


# The behavioural contract the goldens pin. PR 3 added per-job lifecycle
# diagnostics to SimResult (job_submit/start/finish, admission flags,
# fallback counts); those are additive observability, so the golden schema
# stays the original field set and the comparison remains bit-for-bit on it.
GOLDEN_FIELDS = (
    "duration_ns", "start_ns", "goodput_gbps", "correct", "link_utilization",
    "avg_utilization", "stragglers", "collisions", "restorations",
    "retransmissions", "fallbacks", "max_descriptors_per_switch",
    "max_descriptor_bytes", "events", "dropped_packets", "completed_blocks",
)


def result_to_jsonable(result) -> dict:
    """SimResult -> JSON-stable dict (int dict keys become strings)."""
    full = dataclasses.asdict(result)
    d = {k: full[k] for k in GOLDEN_FIELDS}
    d["goodput_gbps"] = {str(k): v for k, v in d["goodput_gbps"].items()}
    # round-trip through the JSON encoder so in-memory results compare equal
    # to goldens loaded from disk (float repr round-trips exactly)
    return json.loads(json.dumps(d))


def load_goldens() -> dict:
    with open(GOLDEN_PATH) as fh:
        return json.load(fh)
