"""Paper §6 ("Support for other collectives"): reduce, broadcast, barrier
built on the Canary machinery."""
import pytest

from repro.core.canary import Algo, AllreduceJob, SimConfig, Simulator


def cfg(**kw):
    base = dict(num_leaves=4, hosts_per_leaf=4, num_spines=4,
                table_size=4096, seed=2, max_events=10_000_000)
    base.update(kw)
    return SimConfig(**base)


def test_reduce_skips_broadcast():
    """reduce: only the destination gets the sum; no broadcast traffic."""
    c = cfg()
    sim = Simulator(c, [AllreduceJob(0, list(range(8)), 32768,
                                     collective="reduce", root=3)],
                    algo=Algo.CANARY)
    r = sim.run()
    assert r.correct
    # no host-downlink broadcast storm: the only busy down-link is the root's
    root_down = sim.net.host_down[3].bytes_sent
    others = [sim.net.host_down[h].bytes_sent for h in range(8) if h != 3]
    assert root_down > 0
    assert all(b <= c.mtu_bytes * 4 for b in others)  # at most stray control


def test_reduce_comparable_to_allreduce():
    """A reduce skips the broadcast phase but funnels every block to one
    destination host (no leader rotation), so it is not strictly faster —
    it must be in the same ballpark and correct."""
    c = cfg()
    red = Simulator(c, [AllreduceJob(0, list(range(8)), 65536,
                                     collective="reduce", root=0)],
                    algo=Algo.CANARY).run()
    allr = Simulator(cfg(), [AllreduceJob(0, list(range(8)), 65536)],
                     algo=Algo.CANARY).run()
    assert red.correct and allr.correct
    assert red.duration_ns <= 1.5 * allr.duration_ns


def test_broadcast_delivers_source_data():
    """broadcast: every participant ends with the source's data."""
    c = cfg()
    sim = Simulator(c, [AllreduceJob(0, [1, 2, 5, 9, 12], 16384,
                                     collective="broadcast", root=5)],
                    algo=Algo.CANARY)
    r = sim.run()
    assert r.correct  # correct == every host got expected_total == source data


def test_barrier_completes_with_header_packets():
    c = cfg()
    sim = Simulator(c, [AllreduceJob(0, list(range(12)), 0,
                                     collective="barrier")],
                    algo=Algo.CANARY)
    r = sim.run()
    assert r.correct
    assert r.completed_blocks == 12  # one barrier block per participant view
    # a barrier moves only header-sized packets: total bytes tiny
    total = sum(l.bytes_sent for l in sim.net.all_links())
    assert total < 12 * 6 * (c.header_bytes + 8 + c.mtu_bytes)


def test_concurrent_mixed_collectives():
    c = cfg(table_size=8192)
    jobs = [
        AllreduceJob(0, [0, 1, 2, 3], 16384),
        AllreduceJob(1, [4, 5, 6, 7], 16384, collective="reduce", root=4),
        AllreduceJob(2, [8, 9, 10, 11], 16384, collective="broadcast",
                     root=8),
        AllreduceJob(3, [12, 13, 14, 15], 0, collective="barrier"),
    ]
    sim = Simulator(c, jobs, algo=Algo.CANARY)
    r = sim.run()
    assert r.correct
    assert len(r.goodput_gbps) == 4
