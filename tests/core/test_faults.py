"""Fault-injection contracts (ARCHITECTURE.md §Faults).

Four layers of guarantees:

* **Observation-only when off** — importing ``repro.core.faults`` and
  leaving ``SimConfig.faults`` empty changes nothing: ``Simulator.faults``
  is ``None`` and every golden replays bit-for-bit.
* **Exactness under faults** — with the ``gbn`` transport, every reduction
  stays *exact* (``correct=True``, all jobs survive) under any fault
  schedule, across CANARY / STATIC_TREE / RING. Without a reliable
  transport, losses are *measured, never hidden*: the per-cause drop split
  conserves (``sum(drop_causes) == dropped_packets``).
* **Graceful degradation** — a capped-generation block retrying onto a dead
  path escalates its app to the §3.3 host-based fallback instead of
  livelocking (pinned on the trace-layer failure scenarios: fat-tree
  spine 5 and three-tier core 17, where flow hashes can pin onto the dead
  path).
* **Acceptance** — congested fat tree + mid-run agg-switch crash +
  recovery: CANARY+gbn completes exactly with a bounded recovery tail,
  STATIC_TREE degrades strictly worse, and the slowdown attribution
  taxonomy gains a conserving ``fault_recovery`` cause.
"""
import pytest
from golden_cases import CASES, _cfg, _jobs, build_simulator, load_goldens, \
    result_to_jsonable

import repro.core.faults  # noqa: F401  (import must not perturb replay)
from repro.core.canary import (Algo, AllreduceJob, SimConfig, Simulator,
                               scaled_config, three_tier_config)
from repro.core.canary.topology import LINK_DOWN_HORIZON
from repro.core.faults import FAULTS, FaultSchedule


def _job(n=8, data_bytes=16384):
    return [AllreduceJob(app=0, participants=list(range(n)),
                         data_bytes=data_bytes)]


# --------------------------------------------------------------------------
# off means off: goldens replay bit-for-bit with the module imported
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def goldens():
    return load_goldens()


@pytest.mark.parametrize("name", sorted(CASES))
def test_goldens_unchanged_with_faults_imported(name, goldens):
    sim = build_simulator(name)
    assert sim.faults is None, "empty schedule must not build a FaultSchedule"
    got = result_to_jsonable(sim.run())
    want = goldens[name]
    for field in sorted(want):
        assert got[field] == want[field], f"{name}: field {field!r} diverged"
    assert got == want


def test_empty_schedule_builds_nothing():
    sim = Simulator(scaled_config(4, faults=[]), _job())
    assert sim.faults is None
    res = sim.run()
    assert res.correct
    assert res.fault_events == []
    assert res.survived == {}


# --------------------------------------------------------------------------
# spec validation: loud errors, at construction time where possible
# --------------------------------------------------------------------------
def test_unknown_fault_kind_raises():
    cfg = scaled_config(4, faults=[{"kind": "gamma_ray", "at_ns": 1.0}])
    with pytest.raises(ValueError, match="unknown fault kind"):
        Simulator(cfg, _job())


@pytest.mark.parametrize("spec", [
    {"kind": "switch_crash", "target": 5, "at_ns": 100.0, "heal_ns": 100.0},
    {"kind": "link_degrade", "target": 0, "at_ns": 1.0, "factor": 1.5},
    {"kind": "link_degrade", "target": 0, "at_ns": 1.0, "factor": 0.0},
    {"kind": "link_flap", "target": 0, "at_ns": 1.0, "down_ns": 500.0,
     "period_ns": 100.0, "cycles": 2},
    {"kind": "link_flap", "target": 0, "at_ns": 1.0, "down_ns": 50.0,
     "period_ns": 100.0, "cycles": 0},
])
def test_bad_fault_params_raise_at_construction(spec):
    with pytest.raises(ValueError):
        Simulator(scaled_config(4, faults=[spec]), _job())


@pytest.mark.parametrize("spec", [
    {"kind": "link_down", "target": "leaf0->nowhere", "at_ns": 10.0,
     "heal_ns": 20.0},
    {"kind": "link_down", "target": 10_000, "at_ns": 10.0, "heal_ns": 20.0},
    {"kind": "switch_crash", "target": 99, "at_ns": 10.0},
    {"kind": "host_slow", "target": 99, "at_ns": 10.0, "heal_ns": 20.0},
])
def test_bad_fault_targets_raise(spec):
    sim = Simulator(scaled_config(4, faults=[spec]), _job())
    with pytest.raises(ValueError):
        sim.run()


def test_registry_is_string_keyed_and_complete():
    assert {"switch_crash", "link_down", "link_degrade", "link_flap",
            "host_slow"} <= set(FAULTS)


# --------------------------------------------------------------------------
# per-kind end-to-end behaviour
# --------------------------------------------------------------------------
def test_switch_crash_and_recovery_end_to_end():
    """Mid-run spine crash + heal under congestion: exact completion, drops
    charged to ``switch_fail``, survivability metrics populated."""
    cfg = scaled_config(8, seed=3, transport="gbn", retx_timeout_ns=5e4,
                        noise_prob=0.05, noise_delay_ns=1000.0,
                        faults=[{"kind": "switch_crash", "target": 10,
                                 "at_ns": 5000.0, "heal_ns": 20000.0}])
    sim = Simulator(cfg, _job(16, 1 << 16),
                    noise_hosts=list(range(16, 32)))
    res = sim.run()
    assert res.correct
    assert res.drop_causes["switch_fail"] > 0
    assert [e["phase"] for e in res.fault_events] == ["fault", "heal"]
    assert res.fault_events[0]["kind"] == "switch_crash"
    assert res.fault_events[0]["t_ns"] == 5000.0
    assert res.survived == {0: True}
    assert res.fault_exposure_ns[0] == pytest.approx(15000.0)
    assert res.fault_recovery_ns[0] > 0.0
    # healed: the crashed spine admits descriptors again, links un-poisoned
    assert not sim.switch.failed[10]
    assert all(l.busy_until < LINK_DOWN_HORIZON
               for l in sim.net.links_into(10))


def test_switch_crash_flushes_descriptor_state():
    """The crash drops the switch's SRAM: descriptor table, slots, armed
    timers — without charging the flushed descriptors as packet drops."""
    cfg = scaled_config(4, seed=3, transport="gbn", retx_timeout_ns=5e4,
                        faults=[{"kind": "switch_crash", "target": 0,
                                 "at_ns": 1500.0, "heal_ns": 60000.0}])
    # crash leaf 0 while its hosts' contributions are aggregating; gbn +
    # retx recovers everything after the heal
    sim = Simulator(cfg, _job(8, 32768))
    res = sim.run()
    assert res.correct
    assert res.survived == {0: True}
    assert not sim.switch.tables[0] or True  # table may refill post-heal
    assert res.retransmissions > 0


def test_link_down_by_name_and_heal():
    cfg = scaled_config(4, seed=5, transport="gbn", retx_timeout_ns=5e4,
                        faults=[{"kind": "link_down",
                                 "target": "leaf0->spine1",
                                 "at_ns": 1000.0, "heal_ns": 30000.0}])
    sim = Simulator(cfg, _job(8, 32768))
    res = sim.run()
    assert res.correct
    assert res.survived == {0: True}
    assert [e["phase"] for e in res.fault_events] == ["fault", "heal"]
    # conservation: every drop is accounted to a cause
    assert sum(v for k, v in res.drop_causes.items()
               if k != "gbn_ooo_discard") == res.dropped_packets


def test_link_degrade_slows_and_restores():
    base = Simulator(scaled_config(4, seed=5), _job(8, 32768)).run()
    # the heal must land inside the run: the engine stops once all jobs
    # complete, so a schedule is clipped to the run's lifetime
    cfg = scaled_config(4, seed=5,
                        faults=[{"kind": "link_degrade",
                                 "target": "host0->leaf0", "factor": 0.02,
                                 "at_ns": 1.0, "heal_ns": 20000.0}])
    sim = Simulator(cfg, _job(8, 32768))
    res = sim.run()
    assert res.correct
    assert res.duration_ns > base.duration_ns, \
        "a 50x slower uplink must lengthen the run"
    # the heal restored the original rate
    idx = sim.net.link_names().index("host0->leaf0")
    clean = Simulator(scaled_config(4, seed=5), _job())
    assert sim.net.all_links()[idx].bytes_per_ns == \
        clean.net.all_links()[idx].bytes_per_ns


def test_link_flap_cycles():
    cfg = scaled_config(4, seed=5, transport="gbn", retx_timeout_ns=5e4,
                        faults=[{"kind": "link_flap",
                                 "target": "leaf1->spine0",
                                 "at_ns": 500.0, "down_ns": 400.0,
                                 "period_ns": 1500.0, "cycles": 3}])
    res = Simulator(cfg, _job(8, 32768)).run()
    assert res.correct
    phases = [e["phase"] for e in res.fault_events]
    assert phases.count("fault") == 3
    assert phases.count("heal") == 3
    # duty cycle: fault edges one period apart
    downs = [e["t_ns"] for e in res.fault_events if e["phase"] == "fault"]
    assert downs == [500.0, 2000.0, 3500.0]


def test_host_slow_parks_and_resumes():
    base = Simulator(scaled_config(4, seed=5), _job(8, 32768)).run()
    cfg = scaled_config(4, seed=5,
                        faults=[{"kind": "host_slow", "target": 0,
                                 "at_ns": 500.0, "heal_ns": 50000.0}])
    res = Simulator(cfg, _job(8, 32768)).run()
    assert res.correct
    assert res.survived == {0: True}
    # host 0 cannot contribute while parked: the run outlasts the heal
    assert res.duration_ns > 45000.0 > base.duration_ns


# --------------------------------------------------------------------------
# property: schedule x algorithm x transport
# --------------------------------------------------------------------------
SCHEDULES = {
    "spine_crash": [
        {"kind": "switch_crash", "target": 5, "at_ns": 3000.0,
         "heal_ns": 40000.0}],
    "link_down": [
        {"kind": "link_down", "target": "leaf1->spine0", "at_ns": 2000.0,
         "heal_ns": 30000.0}],
    "flap_plus_straggler": [
        {"kind": "link_flap", "target": "leaf0->spine2", "at_ns": 2000.0,
         "down_ns": 3000.0, "period_ns": 12000.0, "cycles": 2},
        {"kind": "host_slow", "target": 3, "at_ns": 1000.0,
         "heal_ns": 20000.0}],
}


@pytest.mark.parametrize("sched", sorted(SCHEDULES))
@pytest.mark.parametrize("algo", [Algo.CANARY, Algo.STATIC_TREE, Algo.RING])
def test_gbn_stays_exact_under_any_schedule(algo, sched):
    """The survivability invariant: with go-back-N, every reduction
    completes exactly no matter what the schedule does."""
    cfg = scaled_config(4, seed=7, transport="gbn", retx_timeout_ns=5e4,
                        max_events=20_000_000, faults=SCHEDULES[sched])
    res = Simulator(cfg, _job(8, 16384), algo=algo).run()
    assert res.correct, f"{algo} must stay exact under {sched}"
    assert res.survived == {0: True}


@pytest.mark.parametrize("sched", sorted(SCHEDULES))
def test_faults_without_reliable_transport_measured_not_hidden(sched):
    """Without gbn, fault losses are measured: the per-cause split
    conserves against the total drop counter."""
    cfg = scaled_config(4, seed=7, retx_timeout_ns=5e4,
                        max_events=20_000_000, faults=SCHEDULES[sched])
    res = Simulator(cfg, _job(8, 16384)).run()
    accounted = sum(v for k, v in res.drop_causes.items()
                    if k != "gbn_ooo_discard")
    assert accounted == res.dropped_packets
    assert all(v >= 0 for v in res.drop_causes.values())


# --------------------------------------------------------------------------
# graceful degradation: generation-cap escalation instead of livelock
# --------------------------------------------------------------------------
# A crashed switch with NO heal plus a capped generation budget used to
# livelock: the leader kept flushing and re-arming generations onto state
# the dead switch could never complete. The escalation path flips the whole
# app to the §3.3 host-based fallback the moment the cap trips while a
# fault is live. Same failure scenarios as the trace-layer conservation
# tests: a spine on the 4-leaf fat tree (id 5), a core on the default
# three-tier (id 17) — switches with path redundancy where flow hashes can
# still pin capped-generation traffic onto the dead path.
@pytest.mark.parametrize("fabric,target,at_ns", [
    ("fat_tree", 5, 2000.0),
    ("three_tier", 17, 5000.0),
])
def test_generation_cap_escalates_to_host_fallback(fabric, target, at_ns):
    mk = {"fat_tree": scaled_config,
          "three_tier": lambda **kw: three_tier_config(**kw)}[fabric]
    kw = dict(seed=3, retx_timeout_ns=5e4, max_events=20_000_000,
              max_generations=1, transport="gbn",
              faults=[{"kind": "switch_crash", "target": target,
                       "at_ns": at_ns}])
    cfg = mk(4, **kw) if fabric == "fat_tree" else mk(**kw)
    res = Simulator(cfg, [AllreduceJob(app=0, participants=list(range(10)),
                                       data_bytes=32768)]).run()
    assert res.correct, "escalation must complete the reduction, not hang"
    assert res.survived == {0: True}
    esc = [e for e in res.fault_events if e["phase"] == "escalate"]
    assert esc and esc[0]["target"] == 0, \
        "the capped app must escalate to the host-based fallback"
    assert res.app_fallback_blocks.get(0, 0) > 0


# --------------------------------------------------------------------------
# acceptance: the headline survivability claim, end to end
# --------------------------------------------------------------------------
def test_acceptance_mid_run_crash_canary_degrades_gracefully():
    """Congested fat tree, mid-run aggregation-switch crash + recovery
    (spine 11 — the static tree's root, so both algorithms lose switch
    state): CANARY+gbn completes exactly with a bounded recovery tail and
    strictly less slowdown than STATIC_TREE, and the slowdown attribution
    stays conserving with ``fault_recovery`` in the taxonomy."""
    from repro.core.telemetry import (CAUSES, CONSERVATION_REL_TOL,
                                      attribute_block, view_of)
    crash = [{"kind": "switch_crash", "target": 11, "at_ns": 5000.0,
              "heal_ns": 20000.0}]

    def cell(algo, faults, telemetry=False):
        cfg = scaled_config(8, seed=3, transport="gbn", retx_timeout_ns=5e4,
                            noise_prob=0.05, noise_delay_ns=1000.0,
                            telemetry=telemetry, faults=faults)
        sim = Simulator(cfg, _job(16, 1 << 16), algo=algo,
                        noise_hosts=list(range(16, 32)))
        return sim, sim.run()

    _, canary_clean = cell(Algo.CANARY, [])
    sim, canary_fault = cell(Algo.CANARY, crash, telemetry=True)
    _, static_clean = cell(Algo.STATIC_TREE, [])
    _, static_fault = cell(Algo.STATIC_TREE, crash)

    # exactness + bounded recovery under the fault
    assert canary_fault.correct and canary_fault.survived == {0: True}
    assert 0.0 < canary_fault.fault_recovery_ns[0] < canary_fault.duration_ns

    # graceful degradation: CANARY's dynamic trees re-form around the dead
    # switch; the static tree can only ride out retx timeouts on its root
    canary_slowdown = canary_fault.duration_ns / canary_clean.duration_ns
    static_slowdown = static_fault.duration_ns / static_clean.duration_ns
    assert canary_slowdown < static_slowdown, \
        (f"CANARY slowdown {canary_slowdown:.2f}x must beat STATIC_TREE "
         f"{static_slowdown:.2f}x")

    # attribution: conservation holds and the fault window is charged
    assert "fault_recovery" in CAUSES
    view = view_of(sim.telemetry)
    total_fault_ns = 0.0
    for blk in view.blocks():
        ba = attribute_block(view, blk)
        ba.check()
        assert set(ba.causes) == set(CAUSES)
        tol = max(1e-3, abs(ba.span_ns) * CONSERVATION_REL_TOL)
        assert abs(sum(ba.causes.values()) - ba.span_ns) <= tol
        total_fault_ns += ba.causes.get("fault_recovery", 0.0)
    assert total_fault_ns > 0.0, "the crash window must be attributed"


def test_permanent_crash_without_cap_still_completes():
    """No heal, default generation budget: the LB routes around the dead
    spine and the run completes without needing escalation."""
    cfg = scaled_config(4, seed=3, transport="gbn", retx_timeout_ns=5e4,
                        max_events=20_000_000,
                        faults=[{"kind": "switch_crash", "target": 5,
                                 "at_ns": 2000.0}])
    res = Simulator(cfg, _job(10, 32768)).run()
    assert res.correct
    assert res.survived == {0: True}
