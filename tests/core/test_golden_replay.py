"""Golden-replay regression: the layered engine must reproduce the seed
(pre-refactor, monolithic) simulator bit-for-bit on pinned scenarios.

The goldens were captured from the PR-1 monolith via
``tests/core/capture_goldens.py``. Every ``SimResult`` field — completion
times, event counts, per-link utilization, all protocol counters — must match
exactly; the simulator is fully deterministic given ``SimConfig.seed``.
"""
import pytest

from golden_cases import CASES, build_simulator, load_goldens, result_to_jsonable


@pytest.fixture(scope="module")
def goldens():
    return load_goldens()


@pytest.mark.parametrize("name", sorted(CASES))
def test_replay_matches_golden(name, goldens):
    assert name in goldens, f"golden for {name!r} missing — run capture_goldens"
    got = result_to_jsonable(build_simulator(name).run())
    want = goldens[name]
    # compare field-by-field for readable failures before the full-dict check
    for field in sorted(want):
        assert got[field] == want[field], f"{name}: field {field!r} diverged"
    assert got == want


def test_replay_is_deterministic():
    """Two fresh runs of the same case are identical (no hidden global state)."""
    a = result_to_jsonable(build_simulator("canary_congestion_noise").run())
    b = result_to_jsonable(build_simulator("canary_congestion_noise").run())
    assert a == b
