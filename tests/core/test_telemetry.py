"""Contracts for the telemetry subsystem (ARCHITECTURE.md §Telemetry).

Four layers of guarantees:

* **Observation-only** — every golden replays bit-for-bit with the hub
  enabled: probe ticks dispatch outside the pinned ``events`` count, hooks
  never touch the core RNG or protocol state.
* **Off = no object** — ``Simulator.telemetry`` is ``None`` by default and
  ``SimResult.telemetry_summary`` stays empty; the off path is one pointer
  compare per hook site.
* **Exactness** — the event-driven descriptor series' high-water equals the
  engine's own ``max_descriptors_per_switch`` on congested fat-tree and
  three-tier cells, regardless of the probe cadence.
* **Exporters** — the Perfetto trace-event JSON validates, carries timeout
  -flush spans and per-link backlog counter tracks; the flat dumps
  round-trip every sample.

Plus the satellite pins for ``SimResult.summary()`` rendering (all drop
causes, ``done=-`` for unfinished apps, the throttled-hosts segment).
"""
import dataclasses
import json

import pytest
from golden_cases import (CASES, _cfg, _jobs, load_goldens,
                          result_to_jsonable)

from repro.core.canary import (Algo, AllreduceJob, SimResult, Simulator,
                               scaled_config, three_tier_config)
from repro.core.telemetry import (Telemetry, TimeSeries, run_headline_cell,
                                  to_perfetto, validate_perfetto)
from repro.core.telemetry.metrics import Histogram, MetricsRegistry


def _build(name: str, **cfg_overrides) -> Simulator:
    cfg_kw, jobs_spec, algo, n_trees, noise = CASES[name]
    cfg = _cfg(**{**cfg_kw, **cfg_overrides})
    return Simulator(cfg, _jobs(jobs_spec), algo=algo, n_trees=n_trees,
                     noise_hosts=noise)


# --------------------------------------------------------------------------
# Observation-only: goldens replay bit-identical with the hub on
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def goldens():
    return load_goldens()


@pytest.mark.parametrize("name", sorted(CASES))
def test_goldens_bit_identical_with_telemetry_on(name, goldens):
    got = result_to_jsonable(_build(name, telemetry=True).run())
    assert got == goldens[name], \
        f"golden {name!r} diverged with telemetry enabled"


def test_probe_cadence_does_not_perturb_goldens(goldens):
    """An aggressive probe cadence (50ns) multiplies probe events ~200x;
    the golden contract — including the event count — must still hold."""
    name = "canary_congestion_noise"
    sim = _build(name, telemetry=True, telemetry_probe_ns=50.0)
    assert result_to_jsonable(sim.run()) == goldens[name]
    assert sim.telemetry.probes > 100


# --------------------------------------------------------------------------
# Off = no object
# --------------------------------------------------------------------------
def test_telemetry_off_means_no_hub_object():
    sim = _build("canary_basic")
    assert sim.telemetry is None
    res = sim.run()
    assert res.telemetry_summary == {}


def test_telemetry_on_populates_summary_digest():
    sim = _build("canary_congestion_noise", telemetry=True)
    res = sim.run()
    s = res.telemetry_summary
    assert s["probes"] >= 1
    # the hub counts distinct blocks; SimResult counts per-participant
    # completions (blocks x participants here)
    assert s["blocks/completed"] == s["blocks/started"] > 0
    assert res.completed_blocks % int(s["blocks/completed"]) == 0
    assert s["desc/flush_timeout"] + s["desc/flush_complete"] > 0
    # the digest is asdict-safe (sweep work items round-trip SimResult)
    assert json.loads(json.dumps(dataclasses.asdict(res))) is not None


def test_probes_and_spans_individually_gateable():
    sim = _build("canary_basic", telemetry=True, telemetry_spans=False)
    sim.run()
    assert sim.telemetry.spans == [] and sim.telemetry.instants == []
    assert sim.telemetry.probes >= 1
    sim2 = _build("canary_basic", telemetry=True, telemetry_probes=False)
    sim2.run()
    assert len(sim2.telemetry.spans) > 0
    assert "net/backlog_max_bytes" not in sim2.telemetry.registry.series


# --------------------------------------------------------------------------
# Exactness: occupancy cross-validation (ISSUE satellite 4)
# --------------------------------------------------------------------------
def _congested_fat_tree() -> Simulator:
    cfg = scaled_config(4, seed=3, noise_prob=0.05, telemetry=True)
    n = cfg.num_hosts
    return Simulator(cfg, [AllreduceJob(0, list(range(n // 2)), 1 << 17)],
                     algo=Algo.CANARY, noise_hosts=list(range(n // 2, n)))


def _congested_three_tier() -> Simulator:
    cfg = three_tier_config(num_pods=4, leaves_per_pod=2, hosts_per_leaf=4,
                            aggs_per_pod=2, num_cores=4, seed=11,
                            telemetry=True)
    n = cfg.num_hosts
    return Simulator(cfg, [AllreduceJob(0, list(range(n // 2)), 1 << 16)],
                     algo=Algo.CANARY, noise_hosts=list(range(n // 2, n)))


@pytest.mark.parametrize("build", [_congested_fat_tree, _congested_three_tier],
                         ids=["fat_tree", "three_tier"])
def test_descriptor_high_water_matches_engine_exactly(build):
    """The event-driven per-switch occupancy series must reproduce the
    engine's own high-water counter exactly — the probe cadence only affects
    the sampled aggregate, never the per-switch series."""
    sim = build()
    res = sim.run()
    assert res.correct
    assert res.max_descriptors_per_switch > 0
    tel = sim.telemetry
    assert tel.desc_high_water() == res.max_descriptors_per_switch
    assert tel.summary_dict()["desc_high_water"] == \
        res.max_descriptors_per_switch
    # per-switch series peaks agree with the exact gauge (pre-resolved
    # series for switches that never allocate stay empty — skip those)
    peaks = [int(ts.hi) for k, ts in tel.registry.series.items()
             if k.startswith("switch/") and k.endswith("/descriptors")
             and len(ts)]
    assert max(peaks) == res.max_descriptors_per_switch
    # and the analytic §3.2.2 bound is recorded alongside for comparison
    assert tel.summary_dict()["occupancy_model_descriptors"] > 0


def test_high_water_invariant_under_coarse_cadence():
    """Same cell, probe cadence 100x coarser: identical high-water."""
    fine = _congested_fat_tree()
    fine.run()
    cfg = scaled_config(4, seed=3, noise_prob=0.05, telemetry=True,
                        telemetry_probe_ns=1_000_000.0)
    n = cfg.num_hosts
    coarse = Simulator(cfg, [AllreduceJob(0, list(range(n // 2)), 1 << 17)],
                       algo=Algo.CANARY, noise_hosts=list(range(n // 2, n)))
    coarse.run()
    assert coarse.telemetry.desc_high_water() == \
        fine.telemetry.desc_high_water()


# --------------------------------------------------------------------------
# Exporters
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def headline_sim():
    return run_headline_cell(scale=4, data_bytes=1 << 17)


def test_perfetto_export_validates(headline_sim):
    doc = to_perfetto(headline_sim.telemetry)
    assert validate_perfetto(doc) == []
    assert json.loads(json.dumps(doc)) == doc  # JSON-serializable as-is


def test_perfetto_carries_timeout_spans_and_backlog_series(headline_sim):
    doc = to_perfetto(headline_sim.telemetry)
    ev = doc["traceEvents"]
    timeout_spans = [e for e in ev if e.get("ph") == "b"
                     and e.get("args", {}).get("reason") == "timeout"]
    assert timeout_spans, "congested cell must show timeout flushes"
    backlog = {e["name"] for e in ev if e.get("ph") == "C"
               and e["name"].startswith("link/")}
    assert len(backlog) > 1, "per-link backlog counter tracks expected"
    blocks = [e for e in ev if e.get("ph") == "b" and e["cat"] == "block"]
    assert len(blocks) == int(
        headline_sim.telemetry_result.telemetry_summary["blocks/completed"])


def test_validator_rejects_malformed_documents():
    assert validate_perfetto([]) != []
    assert validate_perfetto({"traceEvents": [{"ph": "?", "name": "x"}]})
    # unbalanced async pair
    bad = {"traceEvents": [
        {"ph": "b", "cat": "c", "id": 1, "pid": 1, "tid": 0, "ts": 0.0,
         "name": "s"}]}
    assert any("unbalanced" in e for e in validate_perfetto(bad))


def test_exporters_handle_zero_spans_and_zero_probes(tmp_path):
    """Edge case: a hub with spans and probes both gated off still exports
    a schema-valid Perfetto document, a header-only CSV and a loadable
    dump (ISSUE satellite: exporter edge cases)."""
    from repro.core.telemetry import (load_dump, to_dump, write_series_csv,
                                      write_series_json)
    sim = _build("canary_basic", telemetry=True, telemetry_spans=False,
                 telemetry_probes=False)
    sim.run()
    tel = sim.telemetry
    assert tel.spans == [] and tel.instants == []
    doc = to_perfetto(tel)
    assert validate_perfetto(doc) == []
    csv_path = tmp_path / "empty.csv"
    assert write_series_csv(tel, str(csv_path)) == 0
    assert csv_path.read_text().splitlines() == ["series,t_ns,value"]
    assert write_series_json(tel, str(tmp_path / "empty.json")) == 0
    # the dump is strict JSON (no NaN/inf extrema sentinels) and loads back
    dump = json.loads(json.dumps(to_dump(tel), allow_nan=False))
    view = load_dump(dump)
    assert view.blocks() == [] and not view.truncated


def test_truncation_counters_round_trip_through_exports():
    """Span-cap truncation must be visible in every export format, not
    silently absorbed (ISSUE satellite: truncation round-trip)."""
    from repro.core.telemetry import load_dump, to_dump
    sim = _build("canary_congestion_noise", telemetry=True,
                 telemetry_max_spans=10)
    sim.run()
    tel = sim.telemetry
    assert tel.spans_dropped > 0
    assert to_perfetto(tel)["otherData"]["spans_dropped"] == tel.spans_dropped
    dump = to_dump(tel)
    assert dump["truncation"]["spans_dropped"] == tel.spans_dropped
    assert load_dump(json.loads(json.dumps(dump))).truncated


def test_series_dumps_round_trip(headline_sim, tmp_path):
    from repro.core.telemetry import write_series_csv, write_series_json
    tel = headline_sim.telemetry
    csv_path, json_path = tmp_path / "s.csv", tmp_path / "s.json"
    n_csv = write_series_csv(tel, str(csv_path))
    n_json = write_series_json(tel, str(json_path))
    assert n_csv == n_json == tel.registry.total_samples()
    lines = csv_path.read_text().splitlines()
    assert lines[0] == "series,t_ns,value"
    assert len(lines) == n_csv + 1
    doc = json.loads(json_path.read_text())
    assert set(doc) == set(tel.registry.series)


# --------------------------------------------------------------------------
# Metrics primitives
# --------------------------------------------------------------------------
def test_time_series_delta_encoding_and_cap():
    ts = TimeSeries(cap=4)
    for t, v in [(0, 1.0), (1, 1.0), (2, 1.0), (3, 2.0), (4, 2.0), (5, 9.0),
                 (6, 0.5), (7, 3.0)]:
        ts.record(t, v)
    # repeats collapse; cap drops the tail but hi/lo track every offer
    assert list(ts.points()) == [(0, 1.0), (3, 2.0), (5, 9.0), (6, 0.5)]
    assert ts.dropped == 1
    assert ts.hi == 9.0 and ts.lo == 0.5


def test_histogram_power_of_two_buckets():
    h = Histogram()
    for v in (1.0, 2.0, 3.0, 1000.0):
        h.observe(v)
    d = h.to_dict()
    assert d["count"] == 4 and d["max"] == 1000.0
    assert h.mean == pytest.approx(251.5)
    assert sum(h.buckets.values()) == 4


def test_registry_counter_gauge_and_span_cap():
    reg = MetricsRegistry(series_cap=8)
    reg.inc("a")
    reg.inc("a", 2.0)
    assert reg.counters["a"] == 3.0
    reg.gauge_max("g", 5)
    reg.gauge_max("g", 3)
    assert reg.gauges["g"] == 5
    # the hub enforces the span cap and reports drops, never raises
    sim = _build("canary_basic", telemetry=True, telemetry_max_spans=10)
    sim.run()
    tel = sim.telemetry
    assert len(tel.spans) + len(tel.instants) <= 20
    assert tel.spans_dropped > 0
    assert tel.summary_dict()["spans_dropped"] == tel.spans_dropped


# --------------------------------------------------------------------------
# summary() rendering pins (ISSUE satellites 1 + 2)
# --------------------------------------------------------------------------
def _result(**kw) -> SimResult:
    base = dict(duration_ns=12_500.0, start_ns=0.0,
                goodput_gbps={0: 40.0}, correct=True, link_utilization=[],
                avg_utilization=0.5, stragglers=0, collisions=0,
                restorations=0, retransmissions=0, fallbacks=0,
                max_descriptors_per_switch=4, max_descriptor_bytes=4096,
                events=100, dropped_packets=0, completed_blocks=8,
                job_finish_ns={0: 12_500.0})
    base.update(kw)
    return SimResult(**base)


def test_summary_renders_every_drop_cause():
    s = _result(drop_causes={"wire": 3, "switch_fail": 1,
                             "gbn_ooo_discard": 7, "cosmic_ray": 2}).summary()
    assert "drops[wire=3,switch_fail=1,gbn_ooo_discard=7,cosmic_ray=2]" in s
    # empty mapping still renders the two core causes as zeros
    assert "drops[wire=0,switch_fail=0]" in _result().summary()


def test_summary_renders_dash_for_unfinished_apps():
    s = _result(goodput_gbps={0: 40.0, 1: 0.0},
                job_finish_ns={0: 12_500.0}).summary()
    assert "app0[done=12.5us" in s
    assert "app1[done=-" in s
    assert "nan" not in s


def test_summary_surfaces_throttled_hosts():
    s = _result(transport="dcqcn",
                transport_stats={"ecn_marks": 5, "cnps": 2},
                host_rate_gbps={3: 25.0, 7: 12.5}).summary()
    assert "throttled[2hosts min=12.5Gbps]" in s
    # no throttled segment when every sender recovered to line rate
    s2 = _result(transport="dcqcn", transport_stats={}).summary()
    assert "throttled" not in s2
    # and none of the transport segment without a policy
    assert "tp=" not in _result().summary()


# --------------------------------------------------------------------------
# Fleet integration: per-tenant series
# --------------------------------------------------------------------------
def test_fleet_driver_merges_per_tenant_series():
    from repro.core.canary import TenantSpec
    from repro.core.fleet import FleetDriver, FleetScenario
    cfg = scaled_config(4, seed=7, telemetry=True, telemetry_probe_ns=500.0)
    jobs = [AllreduceJob(app=0, participants=[0, 1, 2, 3], data_bytes=16384,
                         tenant=0),
            AllreduceJob(app=1, participants=[4, 5, 6, 7], data_bytes=16384,
                         tenant=0),
            AllreduceJob(app=2, participants=[8, 9, 10, 11], data_bytes=16384,
                         tenant=1)]
    scenario = FleetScenario(
        cfg=cfg, tenants=[TenantSpec(0), TenantSpec(1)], jobs=jobs,
        quota_policy="none", baselines=False)
    fr = FleetDriver(scenario).run()
    assert fr.correct
    assert set(fr.tenant_series) == {0, 1}
    for t, series in fr.tenant_series.items():
        assert series[-1][1] == 0.0, "all blocks drained by end of run"
        assert max(v for _, v in series) > 0
    # tenant 0 aggregates two apps, so its peak in-flight count is at least
    # single-app tenant 1's
    assert max(v for _, v in fr.tenant_series[0]) >= \
        max(v for _, v in fr.tenant_series[1])


def test_fleet_driver_skips_series_when_telemetry_off():
    from repro.core.canary import TenantSpec
    from repro.core.fleet import FleetDriver, FleetScenario
    cfg = scaled_config(4, seed=7)
    jobs = [AllreduceJob(app=0, participants=[0, 1, 2, 3], data_bytes=8192,
                         tenant=0)]
    fr = FleetDriver(FleetScenario(cfg=cfg, tenants=[TenantSpec(0)],
                                   jobs=jobs, quota_policy="none",
                                   baselines=False)).run()
    assert fr.tenant_series == {}
