"""The performance contract: the optimized hot path is an *optimization*,
never a behaviour change.

The hot-path overhaul (pre-resolved handler tables, split timer heap with
lazy cancellation, staged per-link arrivals, packet pooling, batched noise
generation, GC pausing) must be observationally invisible:

* every golden scenario replays bit-for-bit — same ``SimResult`` on every
  pinned field *and* the same total ``EventLoop.events`` count (the engine
  dispatches the exact same event sequence; identical per-app completion
  times + link utilizations are only possible if ordering is preserved,
  not just aggregate results);
* packet-pool recycling is exact under the nastiest reuse pressure the
  protocol generates — drops, retransmission generations, collisions and
  broadcast fan-outs sharing one pool;
* the ``max_events`` budget fires *before* dispatch (the pre-overhaul
  engine only noticed after blowing past the limit).
"""
import heapq

import pytest

from golden_cases import CASES, build_simulator, load_goldens, result_to_jsonable
from repro.core.canary import Algo, AllreduceJob, SimConfig, Simulator
from repro.core.canary.engine import (EV_LINK_ARRIVE_SWITCH, EV_PUMP, EV_RETX,
                                      EV_TIMER, EventLoop, N_EVENT_KINDS)
from repro.core.canary.types import PacketPool


@pytest.fixture(scope="module")
def goldens():
    return load_goldens()


# --------------------------------------------------------------- golden sweep
@pytest.mark.parametrize("name", sorted(CASES))
def test_optimized_engine_replays_golden_with_identical_event_count(
        name, goldens):
    """All 15 goldens, bit-for-bit, including the dispatched-event count."""
    sim = build_simulator(name)
    result = sim.run()
    got = result_to_jsonable(result)
    want = goldens[name]
    assert got == want, f"{name}: optimized engine diverged from golden"
    # the SimResult event count is the engine's own dispatch counter — no
    # drift between what ran and what was reported
    assert result.events == sim.engine.events == want["events"]


def test_event_stream_is_exhausted_or_stopped_cleanly():
    """After a run the main heap holds only undispatched future events and
    the engine's stop flag mirrors completion."""
    sim = build_simulator("canary_basic")
    sim.run()
    assert sim.engine.stop
    assert sim.all_done()


# ------------------------------------------------------------------ pool reuse
def _drops_sim(**kw) -> Simulator:
    base = dict(num_leaves=4, hosts_per_leaf=4, num_spines=4, table_size=64,
                seed=5, drop_prob=0.02, retx_timeout_ns=5e4,
                max_events=20_000_000)
    base.update(kw)
    cfg = SimConfig(**base)
    return Simulator(cfg, [AllreduceJob(0, list(range(12)), 65536)],
                     algo=Algo.CANARY)


def test_packet_pool_reuse_exact_under_retransmission_generations():
    """Drops force retransmitted generations (fresh ids, fresh paths) while
    recycled Packet objects flow through every role — host sends, switch
    flushes, collisions (table_size=64 forces them), bypasses, unicasts.
    The reduction must stay exact and the pool must actually be exercised."""
    sim = _drops_sim()
    res = sim.run()
    assert res.correct
    assert res.retransmissions > 0, "cell must exercise retx generations"
    assert res.dropped_packets > 0
    pool = sim.pool
    assert pool.reused > 0, "free list never reused — pooling inert"
    assert pool.freed > 0
    # double-free detector: the free list must never hold the same object
    # twice (a duplicate would alias two future packets onto one object)
    ids = list(map(id, pool._free))
    assert len(ids) == len(set(ids)), "double free detected in packet pool"


def test_packet_pool_never_pools_multicast_packets():
    """Broadcast fan-outs schedule one object on several links; freeing one
    would corrupt the others. Every packet in the free list must be linear."""
    sim = _drops_sim(drop_prob=0.0, table_size=1)  # collisions + restorations
    res = sim.run()
    assert res.correct and res.collisions > 0
    assert all(not p.multicast for p in sim.pool._free)
    # free() resets the guarded fields, so a pooled packet can never leak a
    # stale collision stamp or bypass flag into its next life
    assert all(p.switch_addr == -1 and p.port_stamp == -1 and not p.bypass
               and p.trace_node == -1 for p in sim.pool._free)


def test_packet_pool_reuse_deterministic():
    """Pooling must not introduce hidden cross-run state: two fresh sims
    (each with its own pool) produce identical results."""
    a = result_to_jsonable(_drops_sim().run())
    b = result_to_jsonable(_drops_sim().run())
    assert a == b


def test_pool_alloc_free_roundtrip():
    pool = PacketPool(max_free=2)
    p1, p2, p3 = pool.alloc(), pool.alloc(), pool.alloc()
    assert pool.allocated == 3 and pool.reused == 0
    for p in (p1, p2, p3):
        pool.free(p)
    assert pool.freed == 2, "free list respects max_free"
    q = pool.alloc()
    assert q is p2 and pool.reused == 1  # LIFO reuse


# ------------------------------------------------------- engine budget + heaps
def _noop_handlers():
    calls = []
    def h(a, b, c):
        calls.append((a, b, c))
    return [h] * N_EVENT_KINDS, calls


def test_max_events_budget_checked_before_dispatch():
    """The budget fires *before* dispatch: exactly ``max_events`` events are
    handled, the counter never passes the limit, and the over-budget event
    stays undispatched (pre-overhaul the check ran only after incrementing
    past the limit)."""
    loop = EventLoop()
    handlers, calls = _noop_handlers()
    for i in range(5):
        loop.push(float(i), EV_PUMP, i, 0, None)
    with pytest.raises(RuntimeError, match="event budget"):
        loop.run(handlers, max_events=3)
    assert len(calls) == 3, "exactly max_events events dispatched"
    assert loop.events == 3, "counter must not increment past the budget"
    assert len(loop.heap) == 2, "over-budget events remain queued"


def test_budget_counts_across_run_calls():
    loop = EventLoop()
    handlers, calls = _noop_handlers()
    loop.push(0.0, EV_PUMP, 0, 0, None)
    loop.run(handlers, max_events=10)
    loop.push(1.0, EV_PUMP, 1, 0, None)
    loop.push(2.0, EV_PUMP, 2, 0, None)
    with pytest.raises(RuntimeError):
        loop.run(handlers, max_events=2)  # lifetime budget, already spent 1
    assert loop.events == 2


def test_split_heaps_preserve_global_fifo_order():
    """Timer-heap entries interleave with main-heap entries in exact
    ``(time, seq)`` order — the split changes where an entry waits, never
    when it dispatches. Simultaneous events stay FIFO in push order even
    across the two heaps."""
    loop = EventLoop()
    order = []
    handlers = [lambda a, b, c: order.append(a)] * N_EVENT_KINDS
    loop.push(5.0, EV_PUMP, 0, 0, None)        # seq 1
    loop.push_timer(5.0, EV_TIMER, 1, 0, None)  # seq 2: same t, later seq
    loop.push_timer(3.0, EV_RETX, 2, 0, None)   # seq 3: earliest t
    loop.push(5.0, EV_PUMP, 3, 0, None)        # seq 4
    loop.push_timer(4.0, EV_TIMER, 4, 0, None)  # seq 5
    loop.run(handlers, max_events=100)
    assert order == [2, 4, 0, 1, 3]
    assert loop.events == 5
    assert loop.now == 5.0


def test_staged_link_arrivals_keep_one_heap_entry_per_busy_link():
    """The staged-arrival protocol: N in-flight packets on one link occupy
    one heap entry (the FIFO head); the engine re-arms the next head on pop
    with the (t, seq) assigned at transmit time."""
    from repro.core.canary import scaled_config
    cfg = scaled_config(4, seed=3)
    n = cfg.num_hosts
    sim = Simulator(cfg, [AllreduceJob(0, list(range(n // 2)), 131072)],
                    algo=Algo.CANARY,
                    noise_hosts=list(range(n // 2, n)))
    # drain some events, then audit the invariant mid-flight
    handlers_done = []
    orig = EventLoop.run

    def run_probe(self, handlers, max_events, _heappop=heapq.heappop):
        try:
            orig(self, handlers, 5000)  # partial drain (hits the budget)
        except RuntimeError:
            pass
        staged_links = [e[5] for e in self.heap
                        if e[2] >= EV_LINK_ARRIVE_SWITCH]
        assert staged_links, "expected staged link arrivals mid-run"
        assert len(staged_links) == len(set(map(id, staged_links))), \
            "a busy link must have exactly one heap entry"
        for e in self.heap:
            if e[2] >= EV_LINK_ARRIVE_SWITCH:
                link = e[5]
                assert link.inflight, "armed link with empty FIFO"
                head = link.inflight[0]
                assert (head[0], head[1]) == (e[0], e[1]), \
                    "heap entry must mirror the FIFO head's (t, seq)"
        handlers_done.append(True)
        orig(self, handlers, max_events)  # finish the run

    EventLoop.run = run_probe
    try:
        res = sim.run()
    finally:
        EventLoop.run = orig
    assert handlers_done and res.correct
