"""Leader duplicate-retx suppression.

When several hosts notice the same lost block they all unicast RETX_REQ to
the leader. Only the first may open a failure round; the rest must be
debounced for ``retx_timeout_ns / 2`` (``leader_handle_retx``), otherwise
every duplicate request would bump the generation id and orphan the resends
already in flight for the round that is being recovered.
"""
import pytest

from repro.core.canary import Algo, AllreduceJob, SimConfig, Simulator
from repro.core.canary.types import PacketKind


def _sim(**kw) -> Simulator:
    base = dict(num_leaves=4, hosts_per_leaf=4, num_spines=4, table_size=4096,
                seed=11, retx_timeout_ns=5e4)
    base.update(kw)
    return Simulator(SimConfig(**base),
                     [AllreduceJob(0, list(range(8)), 32768)],
                     algo=Algo.CANARY)


def _fails_queued(sim) -> int:
    hp = sim.hostproto
    return sum(1 for hs in hp.hosts
               for p in hs.queue if p.kind == PacketKind.FAIL)


def test_first_retx_request_opens_a_failure_round():
    sim = _sim()
    hp = sim.hostproto
    leader = sim.leaders[0][0]
    hp.leader_handle_retx(leader, 0, 3, requester=1)
    st = hp.leader_state[(0, 3)]
    assert st.gen == 1
    assert st.last_fail_ns == sim.now
    # FAIL fans out to every other participant of the app
    assert _fails_queued(sim) == len(sim.leaders[0]) - 1


def test_duplicate_requests_inside_half_timeout_are_suppressed():
    sim = _sim()
    hp = sim.hostproto
    leader = sim.leaders[0][0]
    hp.leader_handle_retx(leader, 0, 3, requester=1)
    baseline = _fails_queued(sim)
    # everyone else piles on just before the window closes
    sim.engine.now = sim.cfg.retx_timeout_ns / 2 - 1.0
    for requester in (2, 4, 6):
        hp.leader_handle_retx(leader, 0, 3, requester=requester)
    st = hp.leader_state[(0, 3)]
    assert st.gen == 1, "duplicate request must not bump the generation"
    assert st.last_fail_ns == 0.0, "debounced request must not extend window"
    assert _fails_queued(sim) == baseline, "no second FAIL fan-out"


def test_request_at_window_boundary_opens_a_new_round():
    sim = _sim()
    hp = sim.hostproto
    leader = sim.leaders[0][0]
    hp.leader_handle_retx(leader, 0, 3, requester=1)
    baseline = _fails_queued(sim)
    sim.engine.now = sim.cfg.retx_timeout_ns / 2  # window closed (>=)
    hp.leader_handle_retx(leader, 0, 3, requester=2)
    st = hp.leader_state[(0, 3)]
    assert st.gen == 2
    assert st.last_fail_ns == sim.engine.now
    assert _fails_queued(sim) == 2 * baseline


def test_debounce_window_is_per_block():
    """Block 7's first request must not be absorbed by block 3's window."""
    sim = _sim()
    hp = sim.hostproto
    leader = sim.leaders[0][0]
    hp.leader_handle_retx(leader, 0, 3, requester=1)
    hp.leader_handle_retx(leader, 0, 7, requester=1)
    assert hp.leader_state[(0, 3)].gen == 1
    assert hp.leader_state[(0, 7)].gen == 1


def test_window_scales_with_configured_timeout():
    sim = _sim(retx_timeout_ns=2e5)
    hp = sim.hostproto
    leader = sim.leaders[0][0]
    hp.leader_handle_retx(leader, 0, 0, requester=1)
    sim.engine.now = 9.9e4  # inside 1e5 = retx_timeout_ns / 2
    hp.leader_handle_retx(leader, 0, 0, requester=2)
    assert hp.leader_state[(0, 0)].gen == 1
    sim.engine.now = 1.0e5
    hp.leader_handle_retx(leader, 0, 0, requester=2)
    assert hp.leader_state[(0, 0)].gen == 2


def test_completed_block_bypasses_the_round_machinery():
    """A request for an already-reduced block answers with unicast data and
    never touches generation state (broadcast-phase loss, §3.3)."""
    sim = _sim()
    hp = sim.hostproto
    leader = sim.leaders[0][0]
    hp.completed_total[(0, 3)] = 12345
    hp.leader_handle_retx(leader, 0, 3, requester=5)
    assert (0, 3) not in hp.leader_state
    assert _fails_queued(sim) == 0
    uni = [p for p in hp.hosts[leader].queue
           if p.kind == PacketKind.UNICAST_DATA]
    assert len(uni) == 1 and uni[0].dest == 5 and uni[0].value == 12345
