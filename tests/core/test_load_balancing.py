"""Unit tests for the three up-port selection policies (§2.1, §5.2).

A crafted hot-link scenario preloads backlog on the hash-default up-link and
asserts each policy's defining behaviour: ECMP never moves (congestion
oblivious), ADAPTIVE moves only past the occupancy threshold, PER_PACKET
always takes the least-backlogged port.
"""
import dataclasses

import pytest

from repro.core.canary import (Algo, AllreduceJob, LoadBalancing, SimConfig,
                               Simulator, make_topology)
from repro.core.canary.topology import pick_min_backlog


def _net(lb, **kw):
    base = dict(num_leaves=4, hosts_per_leaf=4, num_spines=4, lb=lb,
                path_aware_lb=False)
    base.update(kw)
    return make_topology(SimConfig(**base))


def _heat(net, leaf, spine, bytes_):
    """Preload ``bytes_`` of backlog on one leaf->spine up-link at t=0."""
    net.leaf_up[leaf][spine].transmit(0.0, bytes_)


FLOW_HASH = 13  # default spine = 13 % 4 = 1


def test_ecmp_is_congestion_oblivious():
    net = _net(LoadBalancing.ECMP)
    default = FLOW_HASH % 4
    _heat(net, 0, default, 10 * net.cfg.buffer_bytes)  # saturate the default
    # ECMP sticks to the hash default no matter the backlog
    assert net.pick_spine(0, now=0.0, flow_hash=FLOW_HASH) == default


def test_per_packet_picks_min_backlog_up_port():
    net = _net(LoadBalancing.PER_PACKET)
    default = FLOW_HASH % 4
    # make every port hot except spine 2, which stays the coolest
    for s, load in enumerate([3000, 5000, 100, 4000]):
        _heat(net, 0, s, load)
    assert net.pick_spine(0, now=0.0, flow_hash=FLOW_HASH) == 2
    # tiny backlog on the default only: any loaded default loses to idle ports
    net2 = _net(LoadBalancing.PER_PACKET)
    _heat(net2, 0, default, 64)
    assert net2.pick_spine(0, now=0.0, flow_hash=FLOW_HASH) != default


def test_per_packet_prefers_default_on_ties():
    """Determinism: with all ports equal, the hash default wins."""
    net = _net(LoadBalancing.PER_PACKET)
    assert net.pick_spine(0, now=0.0, flow_hash=FLOW_HASH) == FLOW_HASH % 4


def test_adaptive_moves_only_past_threshold():
    net = _net(LoadBalancing.ADAPTIVE)
    default = FLOW_HASH % 4
    thr = net.cfg.lb_threshold * net.cfg.buffer_bytes
    # just below threshold: stay on the default
    _heat(net, 0, default, int(thr) - 1024)
    assert net.pick_spine(0, now=0.0, flow_hash=FLOW_HASH) == default
    # push past threshold: adapt to the min-backlog port
    _heat(net, 0, default, 4096)
    assert net.pick_spine(0, now=0.0, flow_hash=FLOW_HASH) != default


def test_adaptive_path_aware_sees_remote_hotspot():
    """CONGA-style path metric: a hot spine->dest-leaf *down* link diverts
    traffic even when the local up-link is idle."""
    net = _net(LoadBalancing.ADAPTIVE, path_aware_lb=True)
    default = FLOW_HASH % 4
    dest_leaf = 2
    net.leaf_down[dest_leaf][default].transmit(0.0, 10 * net.cfg.buffer_bytes)
    got = net.pick_spine(0, now=0.0, flow_hash=FLOW_HASH, dest_leaf=dest_leaf)
    assert got != default
    # the same backlog is invisible to a purely local policy
    net_local = _net(LoadBalancing.ADAPTIVE)
    net_local.leaf_down[dest_leaf][default].transmit(
        0.0, 10 * net_local.cfg.buffer_bytes)
    got_local = net_local.pick_spine(0, now=0.0, flow_hash=FLOW_HASH,
                                     dest_leaf=dest_leaf)
    assert got_local == default


def test_pick_min_backlog_generic_helper():
    """The shared helper (3-tier topologies) mirrors pick_spine semantics."""
    from repro.core.canary.topology import Link
    links = [Link(12.5, 300.0, 131072) for _ in range(3)]
    links[0].transmit(0.0, 9000)
    links[1].transmit(0.0, 100)
    assert pick_min_backlog(links, 0, 0.0, "ecmp", 4096) == 0
    assert pick_min_backlog(links, 0, 0.0, "per_packet", 4096) == 2
    assert pick_min_backlog(links, 0, 0.0, "adaptive", 65536) == 0  # below thr
    assert pick_min_backlog(links, 0, 0.0, "adaptive", 4096) == 2   # above thr


def test_noise_honors_noise_lb_without_flowlets():
    """Background traffic rides cfg.noise_lb on every path — including the
    per-packet (flowlet_lb=False) branch, where the seed monolith silently
    used cfg.lb instead. Pinned here because no golden covers it."""
    from repro.core.canary import Packet, PacketKind

    import random

    from repro.core.canary.engine import EventLoop
    from repro.core.canary.types import PacketPool

    class _StubSim:
        # the facade protocol topologies program against (topology.py
        # docstring): the engine clock + scheduler, drop state, the pool
        now = 0.0
        rng = random.Random(0)
        dropped = 0
        engine = EventLoop()
        pool = PacketPool()
        _drop_prob = 0.0
        _rng_random = None

        def maybe_drop(self):
            return False

    net = _net(LoadBalancing.PER_PACKET, noise_lb=LoadBalancing.ECMP,
               flowlet_lb=False)
    pkt = Packet(kind=PacketKind.NOISE, dest=12, id=0, size_bytes=1024, src=0)
    default = net.flow_hash(pkt) % net.S
    _heat(net, 0, default, 10 * net.cfg.buffer_bytes)  # hot default up-link
    before = net.leaf_up[0][default].bytes_sent
    stub = _StubSim()
    net.bind(stub)
    net.forward_toward_host(stub, 0, pkt)
    # ECMP noise must stay on the (hot) hash default; per_packet would move
    assert net.leaf_up[0][default].bytes_sent == before + pkt.size_bytes


def test_custom_topology_num_switches_from_config():
    """SimConfig.num_switches delegates to the registered topology class."""
    from repro.core.canary import TOPOLOGIES, register_topology
    from repro.core.canary.network import FatTree

    name = "test_counted_fabric"

    @register_topology(name)
    class Counted(FatTree):
        @classmethod
        def config_num_switches(cls, cfg):
            return 123

    try:
        assert SimConfig(topology=name).num_switches == 123
        assert SimConfig().num_switches == 64           # fat_tree default
        cfg3 = SimConfig(topology="three_tier", num_leaves=8, num_pods=4,
                         aggs_per_pod=2, num_cores=4)
        assert cfg3.num_switches == 8 + 8 + 4
    finally:
        TOPOLOGIES.pop(name, None)


@pytest.mark.parametrize("lb", [LoadBalancing.ECMP, LoadBalancing.ADAPTIVE,
                                LoadBalancing.PER_PACKET])
def test_all_policies_end_to_end_correct(lb):
    """Every policy yields exact allreduce results under congestion."""
    cfg = SimConfig(num_leaves=4, hosts_per_leaf=4, num_spines=4, lb=lb,
                    table_size=4096, seed=19, max_events=20_000_000)
    noise = list(range(8, 16))
    sim = Simulator(cfg, [AllreduceJob(0, list(range(8)), 32768)],
                    algo=Algo.CANARY, noise_hosts=noise)
    r = sim.run()
    assert r.correct
