"""§3.2.2 switch-memory occupancy model tests."""
from repro.core.canary import Simulator, AllreduceJob, SimConfig
from repro.core.canary.memory_model import model_for, paper_example


def test_paper_example_175kib():
    m = paper_example()
    # 100 Gb/s, d=5, l=300ns, t=1us, r=1us  ->  ~175 KiB (paper §3.2.2)
    assert abs(m.occupancy_kib - 170.9) < 2.0
    assert m.descriptor_lifetime_ns == 2 * 5 * 1300 + 1000


def test_occupancy_scales_with_bandwidth_and_timeout():
    base = paper_example()
    import dataclasses
    double_bw = dataclasses.replace(base, bandwidth_gbps=200.0)
    assert abs(double_bw.occupancy_bytes - 2 * base.occupancy_bytes) < 1e-6
    double_t = dataclasses.replace(base, timeout_ns=2000.0)
    assert double_t.occupancy_bytes > base.occupancy_bytes


def test_simulated_occupancy_within_model_bound():
    """Measured descriptor high-water x MTU stays within the Little's-law
    bound for the simulated network (diameter 2, generous constant)."""
    cfg = SimConfig(num_leaves=4, hosts_per_leaf=4, num_spines=4,
                    table_size=8192, seed=1)
    sim = Simulator(cfg, [AllreduceJob(0, list(range(12)), 262144)])
    r = sim.run()
    assert r.correct
    model = model_for(cfg, diameter=3)
    # the model bounds bytes-per-allreduce-per-switch; allow 2x slack for
    # burstiness the fluid model does not capture
    assert r.max_descriptor_bytes <= 2.0 * model.occupancy_bytes
