"""§3.2.2 switch-memory occupancy model tests.

Includes the cross-validation suite: the analytic Little's-law bound is
checked against *measured* ``max_descriptor_bytes``/``max_descriptors_per_
switch`` from real simulator runs across timeouts, link speeds and both
topologies. Tolerance is documented at MODEL_SLACK below.
"""
import pytest

from repro.core.canary import (AllreduceJob, SimConfig, Simulator,
                               three_tier_config)
from repro.core.canary.memory_model import model_for, paper_example

# The occupancy model is a fluid bound: packets injected at line rate, one
# descriptor per in-flight MTU, no burstiness. Real runs are bursty (timeout
# flushes, queueing) and the simulator reports a *high-water* mark, so the
# measurement may exceed the fluid average by up to this factor — but never
# more. 2x matches the slack the paper's §5.1 prototype budget implies
# (32K slots provisioned vs ~175 KiB/allreduce modelled).
MODEL_SLACK = 2.0


def test_paper_example_175kib():
    m = paper_example()
    # 100 Gb/s, d=5, l=300ns, t=1us, r=1us  ->  ~175 KiB (paper §3.2.2)
    assert abs(m.occupancy_kib - 170.9) < 2.0
    assert m.descriptor_lifetime_ns == 2 * 5 * 1300 + 1000


def test_occupancy_scales_with_bandwidth_and_timeout():
    base = paper_example()
    import dataclasses
    double_bw = dataclasses.replace(base, bandwidth_gbps=200.0)
    assert abs(double_bw.occupancy_bytes - 2 * base.occupancy_bytes) < 1e-6
    double_t = dataclasses.replace(base, timeout_ns=2000.0)
    assert double_t.occupancy_bytes > base.occupancy_bytes


def test_simulated_occupancy_within_model_bound():
    """Measured descriptor high-water x MTU stays within the Little's-law
    bound for the simulated network (diameter 2, generous constant)."""
    cfg = SimConfig(num_leaves=4, hosts_per_leaf=4, num_spines=4,
                    table_size=8192, seed=1)
    sim = Simulator(cfg, [AllreduceJob(0, list(range(12)), 262144)])
    r = sim.run()
    assert r.correct
    model = model_for(cfg, diameter=3)
    # the model bounds bytes-per-allreduce-per-switch; MODEL_SLACK covers
    # burstiness the fluid model does not capture
    assert r.max_descriptor_bytes <= MODEL_SLACK * model.occupancy_bytes


# ---------------------------------------------------------------------------
# Cross-validation: analytic model vs measured descriptor footprints
# ---------------------------------------------------------------------------
def _measure(cfg: SimConfig, hosts: int = 12,
             data_bytes: int = 262144):
    sim = Simulator(cfg, [AllreduceJob(0, list(range(hosts)), data_bytes)])
    r = sim.run()
    assert r.correct
    return r


@pytest.mark.parametrize("kw", [
    dict(),                        # paper-default timeout/latency
    dict(timeout_ns=500.0),        # shorter aggregation window
    dict(timeout_ns=4000.0),       # longer window -> more soft state
    dict(link_gbps=400.0),         # faster links -> more in flight
])
def test_measured_occupancy_within_model_bound_fat_tree(kw):
    """Little's-law cross-validation on the 2-level fat tree: the measured
    high-water descriptor bytes stay within MODEL_SLACK of the analytic
    bound as timeout and bandwidth vary."""
    cfg = SimConfig(num_leaves=4, hosts_per_leaf=4, num_spines=4,
                    table_size=8192, seed=1, **kw)
    r = _measure(cfg)
    model = model_for(cfg, diameter=3)
    assert 0 < r.max_descriptor_bytes <= MODEL_SLACK * model.occupancy_bytes
    # the two measured fields are one MTU apart by construction
    assert r.max_descriptor_bytes == \
        r.max_descriptors_per_switch * cfg.mtu_bytes


def test_measured_occupancy_within_model_bound_three_tier():
    """Same bound on the 3-tier Clos, with its deeper diameter."""
    cfg = three_tier_config(seed=1, table_size=8192)
    r = _measure(cfg, hosts=16)
    model = model_for(cfg, diameter=4)  # leaf/agg/core: deeper lifetimes
    assert 0 < r.max_descriptor_bytes <= MODEL_SLACK * model.occupancy_bytes


def test_model_bound_scales_like_measurement_with_timeout():
    """Cross-validation of the *trend*: quadrupling the timeout grows the
    measured footprint, and the model bound grows at least as fast (the
    bound may never fall behind the measurement)."""
    lo_cfg = SimConfig(num_leaves=4, hosts_per_leaf=4, num_spines=4,
                       table_size=8192, seed=1, timeout_ns=1000.0)
    hi_cfg = SimConfig(num_leaves=4, hosts_per_leaf=4, num_spines=4,
                       table_size=8192, seed=1, timeout_ns=4000.0)
    lo, hi = _measure(lo_cfg), _measure(hi_cfg)
    assert hi.max_descriptor_bytes >= lo.max_descriptor_bytes
    lo_m = model_for(lo_cfg, diameter=3).occupancy_bytes
    hi_m = model_for(hi_cfg, diameter=3).occupancy_bytes
    assert hi_m > lo_m
    assert hi.max_descriptor_bytes <= MODEL_SLACK * hi_m


def test_fleet_demand_derived_from_model_bounds_measurement():
    """The fleet admission demand (occupancy bytes / MTU, see
    repro.core.fleet.quota.demand_slots) upper-bounds the measured
    per-switch descriptor count of a single job within MODEL_SLACK."""
    from repro.core.fleet import demand_slots
    cfg = SimConfig(num_leaves=4, hosts_per_leaf=4, num_spines=4,
                    table_size=8192, seed=1)
    r = _measure(cfg)
    assert r.max_descriptors_per_switch <= MODEL_SLACK * demand_slots(cfg)
