"""Contracts for the diagnosis layer (ARCHITECTURE.md §Diagnosis).

Four layers of guarantees:

* **Conservation** — per-block cause components sum to the measured span
  within ``CONSERVATION_REL_TOL`` on congested fat_tree and three_tier
  cells, property-tested across seeds/data sizes, and the per-job critical
  path partitions the makespan exactly.
* **Injected bottlenecks name themselves** — each ``scripts/diagnose.py``
  scenario makes one cause dominant on purpose (hot link, table_size=1
  collisions, loss under go-back-N, DCQCN pacing) and the diagnosis must
  rank exactly that cause first.
* **Offline parity** — ``load_dump(to_dump(tel))`` produces the same
  diagnosis as the live ``view_of(tel)``; goldens still replay bit-for-bit
  when a run is diagnosed.
* **Honesty** — truncated telemetry is surfaced prominently in the report,
  and ``scripts/check_regressions.py`` gates artifacts against committed
  baselines with non-zero exit on any breach.
"""
import importlib.util
import json
import os
import sys

import pytest
from golden_cases import CASES, _cfg, _jobs, load_goldens, result_to_jsonable

from repro.core.canary import (Algo, AllreduceJob, Simulator, scaled_config,
                               three_tier_config)
from repro.core.telemetry import (CAUSES, CONSERVATION_REL_TOL, Intervals,
                                  attribute_block, critical_path, diagnose,
                                  hotspots, load_dump, run_headline_cell,
                                  to_dump, view_of)

_SCRIPTS = os.path.join(os.path.dirname(__file__), "..", "..", "scripts")


def _load_script(name: str):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(_SCRIPTS, f"{name}.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _tol(span_ns: float) -> float:
    return max(1e-3, abs(span_ns) * CONSERVATION_REL_TOL)


# --------------------------------------------------------------------------
# Intervals algebra: the foundation of the conservation argument
# --------------------------------------------------------------------------
def test_intervals_normalize_union_intersect_subtract():
    iv = Intervals([(5.0, 7.0), (0.0, 2.0), (1.0, 3.0), (9.0, 9.0)])
    assert iv.spans == [(0.0, 3.0), (5.0, 7.0)]
    assert iv.measure() == 5.0
    other = Intervals([(2.0, 6.0)])
    assert iv.union(other).spans == [(0.0, 7.0)]
    assert iv.intersect(other).spans == [(2.0, 3.0), (5.0, 6.0)]
    assert iv.subtract(other).spans == [(0.0, 2.0), (6.0, 7.0)]
    assert iv.clip(1.0, 5.5).spans == [(1.0, 3.0), (5.0, 5.5)]
    assert Intervals().is_empty()


def test_intervals_algebra_properties_random():
    """Measure-theoretic identities on randomized interval sets: for any
    A, B drawn inside a window W,
    |A| = |A∩B| + |A\\B| and |A∪B| = |A| + |B| - |A∩B|."""
    import random
    rng = random.Random(1234)
    for _ in range(200):
        def rand_set():
            return Intervals([(a, a + rng.uniform(0.0, 3.0))
                              for a in (rng.uniform(0.0, 20.0)
                                        for _ in range(rng.randrange(6)))])
        a_iv, b_iv = rand_set(), rand_set()
        inter = a_iv.intersect(b_iv)
        assert a_iv.measure() == pytest.approx(
            inter.measure() + a_iv.subtract(b_iv).measure(), abs=1e-9)
        assert a_iv.union(b_iv).measure() == pytest.approx(
            a_iv.measure() + b_iv.measure() - inter.measure(), abs=1e-9)
        # subtraction result is disjoint from the subtrahend
        assert a_iv.subtract(b_iv).intersect(b_iv).measure() == 0.0


# --------------------------------------------------------------------------
# Conservation: property-tested on congested cells, both fabrics
# --------------------------------------------------------------------------
def _congested_cell(topology: str, seed: int, data_bytes: int) -> Simulator:
    if topology == "fat_tree":
        cfg = scaled_config(4, seed=seed, noise_prob=0.05, telemetry=True)
    else:
        cfg = three_tier_config(num_pods=4, leaves_per_pod=2,
                                hosts_per_leaf=4, aggs_per_pod=2,
                                num_cores=4, seed=seed, noise_prob=0.05,
                                telemetry=True)
    n = cfg.num_hosts
    return Simulator(cfg, [AllreduceJob(0, list(range(n // 2)), data_bytes)],
                     algo=Algo.CANARY, noise_hosts=list(range(n // 2, n)))


def _assert_conserved(view) -> int:
    """Attribute every block; assert the conservation contract on each.
    Returns the number of blocks checked."""
    blocks = view.blocks()
    for blk in blocks:
        ba = attribute_block(view, blk)
        ba.check()  # raises on violation
        assert abs(sum(ba.causes.values()) - ba.span_ns) <= _tol(ba.span_ns)
        assert set(ba.causes) == set(CAUSES), "closed taxonomy"
        assert all(v >= 0.0 for v in ba.causes.values())
    return len(blocks)


@pytest.mark.parametrize("topology", ["fat_tree", "three_tier"])
@pytest.mark.parametrize("seed,data_bytes",
                         [(3, 1 << 16), (7, 1 << 17), (13, 49152)])
def test_conservation_property_on_congested_cells(topology, seed, data_bytes):
    sim = _congested_cell(topology, seed, data_bytes)
    res = sim.run()
    assert res.correct
    view = view_of(sim.telemetry)
    assert _assert_conserved(view) > 0


@pytest.mark.parametrize("topology", ["fat_tree", "three_tier"])
def test_critical_path_partitions_makespan_exactly(topology):
    """Job-level half of the contract: path segments tile the makespan."""
    sim = _congested_cell(topology, seed=3, data_bytes=1 << 16)
    sim.run()
    view = view_of(sim.telemetry)
    for app in view.apps():
        path = critical_path(view, app)
        blocks = [b for b in view.blocks() if b.app == app]
        makespan = max(b.t1 for b in blocks) - min(b.t0 for b in blocks)
        assert sum(s.span_ns for s in path) == pytest.approx(
            makespan, rel=1e-9)
        # segments are contiguous and ordered
        for prev, nxt in zip(path, path[1:]):
            assert nxt.t0 == pytest.approx(prev.t1, abs=1e-6)


def test_diagnose_runs_conservation_check_on_every_block():
    sim = _congested_cell("fat_tree", seed=3, data_bytes=1 << 16)
    sim.run()
    diag = diagnose(view_of(sim.telemetry))  # check() raises inside on breach
    assert diag.per_block
    assert sum(diag.totals.values()) > 0.0
    # per-app totals equal the sum of that app's path-scaled causes
    for app, aa in diag.per_app.items():
        assert sum(aa.causes.values()) == pytest.approx(aa.makespan_ns,
                                                        rel=1e-6)


# --------------------------------------------------------------------------
# Injected bottlenecks: the diagnosis must name the cause we injected
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def diagnose_script():
    return _load_script("diagnose")


@pytest.mark.parametrize("scenario",
                         ["hot_link", "collisions", "loss_gbn", "dcqcn",
                          "fault"])
def test_injected_bottleneck_is_top_cause(scenario, diagnose_script):
    expected = diagnose_script.SCENARIOS[scenario]["expect"]
    sim = diagnose_script.run_scenario(scenario, scale=4,
                                       data_bytes=1 << 18, seed=3)
    assert sim.telemetry_result.correct
    diag = diagnose(view_of(sim.telemetry))
    assert diag.top_cause() == expected, \
        f"{scenario}: expected {expected}, ranked {diag.ranked()[:3]}"


def test_diagnose_cli_expect_top_exits_nonzero_on_mismatch(diagnose_script,
                                                           tmp_path):
    out = tmp_path / "report.json"
    argv = ["--scenario", "hot_link", "--scale", "4",
            "--data-bytes", str(1 << 18), "--json", str(out)]
    diagnose_script.main(argv)  # default expectation: the injected cause
    doc = json.loads(out.read_text())
    assert doc["top_cause"] == "queueing"
    assert [r["cause"] for r in doc["ranked"]][0] == "queueing"
    with pytest.raises(SystemExit):
        diagnose_script.main(argv + ["--expect-top", "pfc_pause"])


# --------------------------------------------------------------------------
# Offline parity: dump round trip + hotspots
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def headline_sim():
    return run_headline_cell(scale=4, data_bytes=1 << 17)


def test_dump_round_trip_preserves_diagnosis(headline_sim, tmp_path):
    tel = headline_sim.telemetry
    doc = json.loads(json.dumps(to_dump(tel), allow_nan=False))
    live = diagnose(view_of(tel))
    offline = diagnose(load_dump(doc))
    assert offline.totals == live.totals
    assert offline.top_cause() == live.top_cause()
    assert [h.to_dict() for h in offline.hotspots] == \
        [h.to_dict() for h in live.hotspots]
    assert offline.to_json() == live.to_json()
    # and via the file-writing path
    from repro.core.telemetry import write_dump
    p = tmp_path / "dump.json"
    write_dump(tel, str(p))
    assert diagnose(load_dump(str(p))).totals == live.totals


def test_load_dump_rejects_unknown_version():
    with pytest.raises(ValueError):
        load_dump({"version": 99})


def test_hotspots_ranked_with_structural_names(headline_sim):
    view = view_of(headline_sim.telemetry)
    hs = hotspots(view, top=5)
    assert hs and len(hs) <= 5
    assert hs[0].mean_queue_ns >= hs[-1].mean_queue_ns
    # fat-tree structural names, not the generic fallback
    assert all("->" in h.name for h in hs)
    assert all(0.0 <= h.busy_frac <= 1.0 for h in hs)


def test_tenant_windows_split_hotspot_attribution():
    """Two tenants running in disjoint time windows: each tenant's hotspot
    ranking must only see queueing from its own window."""
    cfg = scaled_config(4, seed=5, telemetry=True)
    jobs = [AllreduceJob(app=0, participants=[0, 1, 2, 3],
                         data_bytes=1 << 16, tenant=0),
            AllreduceJob(app=1, participants=[8, 9, 10, 11],
                         data_bytes=1 << 16, tenant=1,
                         arrival_ns=100_000.0)]
    sim = Simulator(cfg, jobs, algo=Algo.CANARY)
    res = sim.run()
    assert res.correct
    diag = diagnose(view_of(sim.telemetry))
    assert set(diag.per_tenant) == {0, 1}
    assert set(diag.tenant_hotspots) == {0, 1}
    h0 = {h.link for h in diag.tenant_hotspots[0]}
    h1 = {h.link for h in diag.tenant_hotspots[1]}
    # disjoint participants on disjoint leaves at disjoint times: the two
    # tenants' host-link hotspots cannot coincide
    n = cfg.num_hosts
    assert not ({l for l in h0 if l < 2 * n} & {l for l in h1 if l < 2 * n})


# --------------------------------------------------------------------------
# Goldens replay bit-for-bit when a run is diagnosed
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def goldens():
    return load_goldens()


@pytest.mark.parametrize("name", sorted(CASES))
def test_goldens_replay_with_diagnosis_enabled(name, goldens):
    cfg_kw, jobs_spec, algo, n_trees, noise = CASES[name]
    sim = Simulator(_cfg(**{**cfg_kw, "telemetry": True}), _jobs(jobs_spec),
                    algo=algo, n_trees=n_trees, noise_hosts=noise)
    assert result_to_jsonable(sim.run()) == goldens[name], \
        f"golden {name!r} diverged with telemetry enabled"
    diag = diagnose(view_of(sim.telemetry))  # conservation-checked inside
    assert diag.top_cause() in CAUSES


# --------------------------------------------------------------------------
# Honesty: truncation surfaces prominently
# --------------------------------------------------------------------------
def test_truncated_telemetry_is_banner_surfaced():
    cfg = scaled_config(4, seed=3, noise_prob=0.05, telemetry=True,
                        telemetry_max_spans=50)
    n = cfg.num_hosts
    sim = Simulator(cfg, [AllreduceJob(0, list(range(n // 2)), 1 << 17)],
                    algo=Algo.CANARY, noise_hosts=list(range(n // 2, n)))
    sim.run()
    tel = sim.telemetry
    assert tel.spans_dropped > 0
    view = view_of(tel)
    assert view.truncated
    diag = diagnose(view)
    assert diag.truncated
    text = diag.to_text()
    assert "TELEMETRY TRUNCATED" in text
    assert "LOWER BOUND" in text
    assert diag.to_json()["truncated"] is True
    # the truncation counters round-trip through the dump exporter
    doc = to_dump(tel)
    assert doc["truncation"]["spans_dropped"] == tel.spans_dropped
    assert load_dump(json.loads(json.dumps(doc))).truncated


def test_untruncated_run_has_no_banner(headline_sim):
    diag = diagnose(view_of(headline_sim.telemetry))
    assert not diag.truncated
    assert "TELEMETRY TRUNCATED" not in diag.to_text()


def test_spans_off_diagnosis_degrades_with_notes():
    cfg = scaled_config(4, seed=3, telemetry=True, telemetry_spans=False)
    sim = Simulator(cfg, [AllreduceJob(0, list(range(8)), 1 << 14)],
                    algo=Algo.CANARY)
    sim.run()
    diag = diagnose(view_of(sim.telemetry))
    assert diag.per_block == [] and diag.per_app == {}
    assert any("no block spans" in n for n in diag.notes)
    assert "note:" in diag.to_text()


# --------------------------------------------------------------------------
# Regression gate: scripts/check_regressions.py
# --------------------------------------------------------------------------
@pytest.fixture()
def gate(tmp_path):
    mod = _load_script("check_regressions")

    def run(baselines: dict, artifacts: dict, extra_argv=()):
        bpath = tmp_path / "baselines.json"
        bpath.write_text(json.dumps(baselines))
        for name, doc in artifacts.items():
            (tmp_path / name).write_text(json.dumps(doc))
        mod.main(["--baselines", str(bpath), "--dir", str(tmp_path),
                  *extra_argv])
    return run


def test_gate_passes_within_bounds(gate):
    gate({"files": {"R.json": {"any": {
            "cells.a.speedup": {"min": 1.2},
            "cells.a.events": {"ref": 100, "rel_tol": 0},
            "failed": {"empty": True},
            "ok": {"equals": True}}}}},
         {"R.json": {"cells": {"a": {"speedup": 1.5, "events": 100}},
                     "failed": [], "ok": True}})


@pytest.mark.parametrize("artifact", [
    {"cells": {"a": {"speedup": 1.1, "events": 100}},
     "failed": [], "ok": True},              # speedup below floor
    {"cells": {"a": {"speedup": 1.5, "events": 101}},
     "failed": [], "ok": True},              # event count drifted (rel_tol 0)
    {"cells": {"a": {"speedup": 1.5, "events": 100}},
     "failed": ["fig7"], "ok": True},        # failed suite recorded
    {"cells": {"a": {"speedup": 1.5}},
     "failed": [], "ok": True},              # path missing from artifact
], ids=["below-min", "ref-drift", "non-empty", "missing-path"])
def test_gate_exits_nonzero_on_breach(gate, artifact):
    with pytest.raises(SystemExit):
        gate({"files": {"R.json": {"any": {
                "cells.a.speedup": {"min": 1.2},
                "cells.a.events": {"ref": 100, "rel_tol": 0},
                "failed": {"empty": True},
                "ok": {"equals": True}}}}},
             {"R.json": artifact})


def test_gate_profile_key_selects_fast_or_full(gate):
    base = {"files": {"R.json": {
        "profile_key": "fast",
        "fast": {"n": {"ref": 10, "rel_tol": 0}},
        "full": {"n": {"ref": 20, "rel_tol": 0}}}}}
    gate(base, {"R.json": {"fast": True, "n": 10}})
    gate(base, {"R.json": {"fast": False, "n": 20}})
    with pytest.raises(SystemExit):
        gate(base, {"R.json": {"fast": True, "n": 20}})


def test_gate_missing_artifact_skips_unless_required(gate):
    base = {"files": {"ABSENT.json": {"any": {"x": {"min": 1}}}}}
    gate(base, {})  # skip, no error
    with pytest.raises(SystemExit):
        gate(base, {}, extra_argv=["--require-all"])


def test_committed_baselines_parse_and_gate_runs():
    """The checked-in baseline file is well-formed: every constraint object
    uses only known keys and the gate accepts it end to end."""
    path = os.path.join(_SCRIPTS, "..", "benchmarks",
                        "regression_baselines.json")
    with open(path) as f:
        base = json.load(f)
    known = {"min", "max", "ref", "rel_tol", "equals", "empty", "reason"}
    for rules in base["files"].values():
        for profile in ("any", "fast", "full"):
            for dotted, spec in rules.get(profile, {}).items():
                assert set(spec) <= known, (dotted, spec)
                assert set(spec) - {"reason"}, f"no-op constraint: {dotted}"
