"""End-to-end coverage of the pluggable topology layer.

The 2-level fat tree is pinned by the golden-replay suite; these tests cover
the 3-tier folded Clos (``three_tier``) and the topology registry itself.
"""
import pytest

from repro.core.canary import (Algo, AllreduceJob, SimConfig, Simulator,
                               TOPOLOGIES, compare_algorithms, make_topology,
                               three_tier_config)


def cfg3(**kw):
    base = dict(seed=3, max_events=20_000_000)
    base.update(kw)
    return three_tier_config(**base)


def test_registry_contains_both_topologies():
    assert "fat_tree" in TOPOLOGIES
    assert "three_tier" in TOPOLOGIES
    with pytest.raises(ValueError, match="unknown topology"):
        make_topology(SimConfig(topology="nope"))


def test_three_tier_shape():
    net = make_topology(cfg3())
    # 4 pods x 2 leaves x 4 hosts, 2 aggs/pod, 4 cores
    assert net.num_hosts == 32
    assert net.num_switches == 8 + 8 + 4
    assert net.is_leaf(0) and net.is_leaf(7)
    assert net.is_agg(8) and net.is_agg(15)
    assert not net.is_leaf(16) and not net.is_agg(16)
    # oversubscribed: 4 host downlinks vs 2 agg uplinks per leaf
    assert len(net.leaf_up[0]) == 2
    assert net.is_up_port(0, 5) and not net.is_up_port(0, 3)


@pytest.mark.parametrize("algo,n_trees", [
    (Algo.CANARY, 1), (Algo.STATIC_TREE, 1), (Algo.STATIC_TREE, 4),
    (Algo.RING, 1),
])
def test_three_tier_allreduce_correct(algo, n_trees):
    sim = Simulator(cfg3(), [AllreduceJob(0, list(range(12)), 65536)],
                    algo=algo, n_trees=n_trees)
    r = sim.run()
    assert r.correct
    assert r.duration_ns > 0


def test_three_tier_cross_pod_participants():
    """Participants spread one per pod force 4-hop (leaf/agg/core) paths."""
    cfg = cfg3()
    parts = [0, 8, 16, 24]  # host 0 of each pod
    sim = Simulator(cfg, [AllreduceJob(0, parts, 32768)], algo=Algo.CANARY)
    r = sim.run()
    assert r.correct
    # cross-pod traffic must traverse agg->core links
    net = sim.net
    core_bytes = sum(l.bytes_sent for row in net.agg_up for l in row)
    assert core_bytes > 0


def test_three_tier_reliability_drops():
    cfg = cfg3(drop_prob=0.01, retx_timeout_ns=5e4, seed=5)
    sim = Simulator(cfg, [AllreduceJob(0, list(range(8)), 16384)],
                    algo=Algo.CANARY)
    r = sim.run()
    assert r.correct
    assert r.dropped_packets > 0


def test_three_tier_core_failure_recovered():
    """A core switch dying mid-run is recovered by retransmission (§3.3)."""
    cfg = cfg3(switch_fail_ns=2000.0, failed_switch=16,  # first core
               retx_timeout_ns=5e4, seed=7)
    parts = [0, 4, 8, 12, 16, 20, 24, 28]  # spread across all pods
    sim = Simulator(cfg, [AllreduceJob(0, parts, 32768)], algo=Algo.CANARY)
    r = sim.run()
    assert r.correct


def test_three_tier_mixed_collectives():
    cfg = cfg3()
    jobs = [
        AllreduceJob(0, [0, 1, 2, 3], 16384),
        AllreduceJob(1, [4, 5, 6, 7], 16384, collective="reduce", root=4),
        AllreduceJob(2, [8, 9, 10, 11], 16384, collective="broadcast", root=8),
        AllreduceJob(3, [12, 13, 14, 15], 0, collective="barrier"),
    ]
    r = Simulator(cfg, jobs, algo=Algo.CANARY).run()
    assert r.correct
    assert len(r.goodput_gbps) == 4


def test_three_tier_through_compare_algorithms():
    """Acceptance: a non-2-level topology runs the paper's core comparison
    end-to-end, congestion included."""
    out = compare_algorithms(cfg3(), 16, 65536, congestion=True, reps=1)
    assert set(out) == {"ring", "static_1", "static_4", "canary"}
    for name, res in out.items():
        assert res.correct, name
        assert res.goodput_gbps_mean > 0, name


def test_three_tier_deterministic():
    a = Simulator(cfg3(), [AllreduceJob(0, list(range(10)), 32768)],
                  algo=Algo.CANARY).run()
    b = Simulator(cfg3(), [AllreduceJob(0, list(range(10)), 32768)],
                  algo=Algo.CANARY).run()
    assert a.duration_ns == b.duration_ns and a.events == b.events
