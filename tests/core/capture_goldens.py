"""Regenerate the simulator golden-replay fixtures.

Run from the repo root::

    PYTHONPATH=src python tests/core/capture_goldens.py

Only do this when a behaviour change is *intentional*; the whole point of the
goldens is to prove structural refactors leave behaviour bit-identical.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

from golden_cases import CASES, GOLDEN_PATH, build_simulator, result_to_jsonable


def main() -> None:
    out = {}
    for name in CASES:
        sim = build_simulator(name)
        result = sim.run()
        if not result.correct:
            raise SystemExit(f"case {name!r} produced an incorrect run; "
                             "refusing to capture a broken golden")
        out[name] = result_to_jsonable(result)
        print(f"{name}: events={result.events} duration_ns={result.duration_ns}")
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    with open(GOLDEN_PATH, "w") as fh:
        json.dump(out, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"wrote {len(out)} goldens -> {GOLDEN_PATH}")


if __name__ == "__main__":
    main()
