"""End-to-end behaviour tests for the Canary simulator (paper §3-§5).

Every test asserts *numerical correctness* of the allreduce — the simulator
carries exact integer payloads, so ``result.correct`` proves every participant
received the true sum for every block.
"""
import dataclasses

import pytest

from repro.core.canary import (Algo, AllreduceJob, SimConfig, Simulator,
                               run_allreduce, scaled_config)


def tiny_cfg(**kw):
    base = dict(num_leaves=4, hosts_per_leaf=4, num_spines=4,
                table_size=4096, seed=11, max_events=20_000_000)
    base.update(kw)
    return SimConfig(**base)


@pytest.mark.parametrize("algo,n_trees", [
    (Algo.CANARY, 1), (Algo.STATIC_TREE, 1), (Algo.STATIC_TREE, 4),
    (Algo.RING, 1),
])
def test_allreduce_correct_no_congestion(algo, n_trees):
    r = run_allreduce(tiny_cfg(), algo, 8, 32768, n_trees=n_trees,
                      congestion=False, reps=1)
    assert r.correct
    assert r.goodput_gbps_mean > 0


@pytest.mark.parametrize("algo", [Algo.CANARY, Algo.STATIC_TREE, Algo.RING])
def test_allreduce_correct_under_congestion(algo):
    r = run_allreduce(tiny_cfg(), algo, 8, 32768, congestion=True, reps=1)
    assert r.correct


def test_canary_small_single_block():
    cfg = tiny_cfg()
    sim = Simulator(cfg, [AllreduceJob(0, [0, 1], cfg.payload_bytes)],
                    algo=Algo.CANARY)
    assert sim.run().correct


def test_participants_on_same_leaf():
    cfg = tiny_cfg()
    sim = Simulator(cfg, [AllreduceJob(0, [0, 1, 2, 3], 8192)], algo=Algo.CANARY)
    assert sim.run().correct


def test_participants_spread_one_per_leaf():
    cfg = tiny_cfg()
    sim = Simulator(cfg, [AllreduceJob(0, [0, 4, 8, 12], 8192)],
                    algo=Algo.CANARY)
    assert sim.run().correct


def test_single_participant_degenerate():
    cfg = tiny_cfg()
    sim = Simulator(cfg, [AllreduceJob(0, [3], 4096)], algo=Algo.CANARY)
    r = sim.run()
    assert r.correct and r.duration_ns == 0.0


def test_stragglers_with_tiny_timeout_still_correct():
    """§3.1.1: a too-short timeout creates stragglers but never wrong sums."""
    cfg = tiny_cfg(timeout_ns=50.0)   # far below per-hop latency
    sim = Simulator(cfg, [AllreduceJob(0, list(range(12)), 65536)],
                    algo=Algo.CANARY)
    r = sim.run()
    assert r.correct
    assert r.stragglers > 0


def test_large_timeout_slower_but_correct():
    slow = Simulator(tiny_cfg(timeout_ns=20000.0),
                     [AllreduceJob(0, list(range(8)), 16384)], algo=Algo.CANARY)
    fast = Simulator(tiny_cfg(timeout_ns=1000.0),
                     [AllreduceJob(0, list(range(8)), 16384)], algo=Algo.CANARY)
    rs, rf = slow.run(), fast.run()
    assert rs.correct and rf.correct
    # small allreduce: latency dominated by the timeout (§5.2.3)
    assert rs.duration_ns > rf.duration_ns


def test_collisions_trigger_tree_restoration():
    """§3.2.1: with a 1-entry descriptor table every concurrent block beyond
    the first collides; restoration must still deliver correct results."""
    cfg = tiny_cfg(table_size=1)
    sim = Simulator(cfg, [AllreduceJob(0, list(range(8)), 16384)],
                    algo=Algo.CANARY)
    r = sim.run()
    assert r.correct
    assert r.collisions > 0
    assert r.restorations > 0


def test_collision_free_with_partitioned_table():
    """§3.2.1/§6: statically partitioning the table across apps removes
    cross-app collisions entirely when each partition is large enough."""
    cfg = tiny_cfg(table_size=8192, partition_table=True)
    jobs = [AllreduceJob(0, [0, 1, 2, 3], 8192),
            AllreduceJob(1, [4, 5, 6, 7], 8192)]
    sim = Simulator(cfg, jobs, algo=Algo.CANARY)
    r = sim.run()
    assert r.correct


def test_multitenancy_concurrent_apps():
    """§3.4: concurrent allreduces of different applications coexist."""
    cfg = tiny_cfg()
    jobs = [AllreduceJob(a, list(range(a * 4, a * 4 + 4)), 16384)
            for a in range(3)]
    sim = Simulator(cfg, jobs, algo=Algo.CANARY)
    r = sim.run()
    assert r.correct
    assert len(r.goodput_gbps) == 3
    assert all(g > 0 for g in r.goodput_gbps.values())


def test_packet_loss_recovered_by_retransmission():
    """§3.3: iid packet drops are detected by host timers and repaired."""
    cfg = tiny_cfg(drop_prob=0.01, retx_timeout_ns=5e4, seed=5)
    sim = Simulator(cfg, [AllreduceJob(0, list(range(8)), 16384)],
                    algo=Algo.CANARY)
    r = sim.run()
    assert r.correct
    assert r.dropped_packets > 0
    assert r.retransmissions > 0


def test_heavy_packet_loss_falls_back():
    cfg = tiny_cfg(drop_prob=0.05, retx_timeout_ns=3e4, max_generations=2,
                   seed=9)
    sim = Simulator(cfg, [AllreduceJob(0, list(range(6)), 8192)],
                    algo=Algo.CANARY)
    r = sim.run()
    assert r.correct


def test_switch_failure_treated_as_loss():
    """§3.3: a spine dying mid-run only costs retransmission of in-flight
    blocks; the reduction completes without restarting from scratch."""
    cfg = tiny_cfg(switch_fail_ns=2000.0, failed_switch=4 + 1,  # spine 1
                   retx_timeout_ns=5e4, seed=3)
    sim = Simulator(cfg, [AllreduceJob(0, list(range(10)), 32768)],
                    algo=Algo.CANARY)
    r = sim.run()
    assert r.correct
    assert r.retransmissions > 0


def test_noise_delays_still_correct():
    """§5.2.5: sender-side OS noise delays packets; aggregation is best-effort
    but the result is exact."""
    cfg = tiny_cfg(noise_prob=0.10, noise_delay_ns=1000.0)
    sim = Simulator(cfg, [AllreduceJob(0, list(range(8)), 32768)],
                    algo=Algo.CANARY)
    r = sim.run()
    assert r.correct


def test_descriptor_soft_state_is_freed():
    """§3.2: descriptors are deallocated by the broadcast sweep; at the end of
    a clean run no descriptor may linger."""
    cfg = tiny_cfg()
    sim = Simulator(cfg, [AllreduceJob(0, list(range(8)), 16384)],
                    algo=Algo.CANARY)
    r = sim.run()
    assert r.correct
    leftover = sum(len(t) for t in sim.tables)
    assert leftover == 0


def test_memory_bound_independent_of_data_size():
    """§3.2.2: descriptor high-water is bounded by the bandwidth-delay
    product, not by the reduced-data size."""
    cfg = tiny_cfg()
    hw = []
    for size in (16384, 65536, 262144):
        sim = Simulator(cfg, [AllreduceJob(0, list(range(8)), size)],
                        algo=Algo.CANARY)
        r = sim.run()
        assert r.correct
        hw.append(r.max_descriptors_per_switch)
    # growing the data 16x must not grow the high-water 16x
    assert hw[2] < 16 * hw[0] + 8


def test_in_network_beats_ring_without_congestion():
    """Fig. 2: in-network allreduce ~2x host-based ring."""
    cfg = scaled_config(4, seed=2)
    ring = run_allreduce(cfg, Algo.RING, 8, 262144, reps=1)
    canary = run_allreduce(cfg, Algo.CANARY, 8, 262144, reps=1)
    assert canary.correct and ring.correct
    assert canary.goodput_gbps_mean > 1.5 * ring.goodput_gbps_mean


def test_canary_beats_single_static_tree_under_congestion():
    """Fig. 7/8: with background traffic Canary outperforms one static tree."""
    cfg = scaled_config(8, seed=3)
    st = run_allreduce(cfg, Algo.STATIC_TREE, 32, 524288, n_trees=1,
                       congestion=True, reps=2)
    ca = run_allreduce(cfg, Algo.CANARY, 32, 524288, congestion=True, reps=2)
    assert ca.correct and st.correct
    assert ca.goodput_gbps_mean > st.goodput_gbps_mean


def test_static_tree_counters_exact():
    cfg = tiny_cfg()
    sim = Simulator(cfg, [AllreduceJob(0, list(range(16)), 16384)],
                    algo=Algo.STATIC_TREE, n_trees=2)
    r = sim.run()
    assert r.correct
    assert r.stragglers == 0 and r.collisions == 0


def test_ring_with_unaligned_sizes():
    cfg = tiny_cfg()
    sim = Simulator(cfg, [AllreduceJob(0, [0, 1, 2, 5, 9, 10, 14], 10000)],
                    algo=Algo.RING)
    assert sim.run().correct
