"""The plug-in contracts ARCHITECTURE.md promises: algorithms and topologies
register at runtime, by key, without touching engine/facade code."""
import dataclasses

import pytest

from repro.core.canary import (ALGORITHMS, Algo, AllreduceJob, SimConfig,
                               Simulator, StaticTreeStrategy, TOPOLOGIES,
                               register_algorithm, register_topology,
                               run_allreduce)
from repro.core.canary.network import FatTree


def _cfg(**kw):
    base = dict(num_leaves=4, hosts_per_leaf=4, num_spines=4, table_size=4096,
                seed=1, max_events=10_000_000)
    base.update(kw)
    return SimConfig(**base)


def test_builtin_algorithms_registered_by_value():
    assert {"canary", "static_tree", "ring"} <= set(ALGORITHMS)
    assert ALGORITHMS[str(Algo.CANARY)] is ALGORITHMS["canary"]


def test_custom_algorithm_key_runs_end_to_end():
    """A new collective registers under a fresh string key — no Algo enum
    edit, no engine change."""
    key = "test_static_clone"
    register_algorithm(key)(type("Clone", (StaticTreeStrategy,), {}))
    try:
        r = Simulator(_cfg(), [AllreduceJob(0, list(range(8)), 16384)],
                      algo=key).run()
        assert r.correct
    finally:
        ALGORITHMS.pop(key, None)


def test_unknown_algorithm_errors_with_registered_list():
    with pytest.raises(ValueError, match="no strategy registered"):
        Simulator(_cfg(), [AllreduceJob(0, [0, 1], 1024)], algo="nope")


def test_custom_topology_selectable_via_config():
    name = "test_slow_fat_tree"

    @register_topology(name)
    class SlowFatTree(FatTree):
        def __init__(self, cfg):
            super().__init__(dataclasses.replace(
                cfg, hop_latency_ns=cfg.hop_latency_ns * 2))

    try:
        slow = Simulator(_cfg(topology=name),
                         [AllreduceJob(0, list(range(8)), 16384)]).run()
        base = Simulator(_cfg(),
                         [AllreduceJob(0, list(range(8)), 16384)]).run()
        assert slow.correct and base.correct
        assert slow.duration_ns > base.duration_ns
    finally:
        TOPOLOGIES.pop(name, None)


def test_lone_noise_host_terminates():
    """A congestion workload with a single noise host has no peer to stream
    to; the run must complete instead of spinning in peer selection."""
    cfg = _cfg()
    r = run_allreduce(cfg, Algo.CANARY, cfg.num_hosts - 1, 16384,
                      congestion=True, reps=1)
    assert r.correct
