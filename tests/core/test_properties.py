"""Hypothesis property tests on system invariants."""
import dataclasses

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False

from repro.core.canary import (Algo, AllreduceJob, SimConfig, Simulator)

pytestmark = pytest.mark.skipif(not HAVE_HYP, reason="hypothesis missing")


def _cfg(**kw):
    base = dict(num_leaves=2, hosts_per_leaf=4, num_spines=2,
                table_size=512, seed=0, max_events=5_000_000)
    base.update(kw)
    return SimConfig(**base)


if HAVE_HYP:
    @given(
        n_hosts=st.integers(2, 8),
        blocks_bytes=st.integers(1, 8192),
        timeout=st.floats(100.0, 5000.0),
        seed=st.integers(0, 1000),
        algo=st.sampled_from([Algo.CANARY, Algo.STATIC_TREE, Algo.RING]),
    )
    @settings(max_examples=30, deadline=None)
    def test_allreduce_always_exact(n_hosts, blocks_bytes, timeout, seed, algo):
        """Invariant: any parameterization yields exact sums at all hosts."""
        cfg = _cfg(timeout_ns=timeout, seed=seed)
        import random
        rng = random.Random(seed)
        parts = rng.sample(range(cfg.num_hosts), n_hosts)
        sim = Simulator(cfg, [AllreduceJob(0, parts, blocks_bytes)], algo=algo)
        r = sim.run()
        assert r.correct

    @given(
        table=st.integers(1, 64),
        seed=st.integers(0, 100),
    )
    @settings(max_examples=20, deadline=None)
    def test_collisions_never_corrupt(table, seed):
        """Invariant: however small the descriptor table (any collision
        rate), tree restoration preserves exactness."""
        cfg = _cfg(table_size=table, seed=seed)
        sim = Simulator(cfg, [AllreduceJob(0, list(range(6)), 16384)],
                        algo=Algo.CANARY)
        r = sim.run()
        assert r.correct

    @given(drop=st.floats(0.0, 0.03), seed=st.integers(0, 50))
    @settings(max_examples=15, deadline=None)
    def test_losses_always_recovered(drop, seed):
        cfg = _cfg(drop_prob=drop, retx_timeout_ns=4e4, seed=seed)
        sim = Simulator(cfg, [AllreduceJob(0, list(range(5)), 8192)],
                        algo=Algo.CANARY)
        r = sim.run()
        assert r.correct

    @given(sizes=st.lists(st.integers(1024, 131072), min_size=2, max_size=3),
           seed=st.integers(0, 20))
    @settings(max_examples=10, deadline=None)
    def test_multitenant_isolation(sizes, seed):
        """Concurrent tenants never corrupt each other's sums."""
        cfg = _cfg(seed=seed, num_leaves=4, hosts_per_leaf=4, num_spines=4)
        jobs = [AllreduceJob(a, list(range(a * 4, a * 4 + 4)), s)
                for a, s in enumerate(sizes)]
        sim = Simulator(cfg, jobs, algo=Algo.CANARY)
        r = sim.run()
        assert r.correct

    @given(seed=st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_descriptor_bound_holds(seed):
        """Descriptor occupancy stays within 2x the Little's-law bound."""
        from repro.core.canary.memory_model import model_for
        cfg = _cfg(seed=seed)
        sim = Simulator(cfg, [AllreduceJob(0, list(range(8)), 65536)],
                        algo=Algo.CANARY)
        r = sim.run()
        bound = model_for(cfg, diameter=3).occupancy_bytes
        assert r.max_descriptor_bytes <= 2.0 * bound
