"""Unit + property tests for the §4.2 shard-encoded multicast groups."""
import random

import pytest

from repro.core.canary.multicast import (bitmap_to_ports, build_rule_table,
                                         multicast_ports, num_rules,
                                         ports_to_bitmap, shard_bitmap,
                                         shard_to_ports)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


def test_paper_example():
    """§4.2's worked example: 8 ports, bitmap 00101101 -> shards 1|0010, 0|1101."""
    bm = ports_to_bitmap([0, 2, 3, 5], 8)
    assert bm == 0b00101101
    shards = shard_bitmap(bm, 8, 2)
    assert shards == [(0, 0b1101), (1, 0b0010)]
    assert shard_to_ports(0, 0b1101, 8, 2) == [0, 2, 3]
    assert shard_to_ports(1, 0b0010, 8, 2) == [5]
    assert multicast_ports(bm, 8, 2) == [0, 2, 3, 5]


def test_rule_count_matches_paper_formula():
    """§4.2: 64-port switch with 4 shards -> 256Ki rules (vs 2^64)."""
    assert num_rules(64, 4) == 4 * 2 ** 16 == 262144
    assert num_rules(8, 2) == 2 * 2 ** 4


def test_rule_table_small():
    table = build_rule_table(8, 2)
    assert len(table) == 2 * (2 ** 4 - 1)
    assert table[(0, 0b1101)] == [0, 2, 3]
    assert table[(1, 0b0010)] == [5]


def test_roundtrip_exhaustive_8_ports():
    for bm in range(256):
        assert multicast_ports(bm, 8, 2) == bitmap_to_ports(bm)
        assert multicast_ports(bm, 8, 4) == bitmap_to_ports(bm)


def test_invalid_port_raises():
    with pytest.raises(ValueError):
        ports_to_bitmap([9], 8)
    with pytest.raises(ValueError):
        shard_bitmap(0b1, 10, 4)  # 10 % 4 != 0


if HAVE_HYPOTHESIS:
    @given(st.sets(st.integers(min_value=0, max_value=63)),
           st.sampled_from([1, 2, 4, 8]))
    @settings(max_examples=200, deadline=None)
    def test_roundtrip_property_64_ports(ports, shards):
        bm = ports_to_bitmap(sorted(ports), 64)
        assert multicast_ports(bm, 64, shards) == sorted(ports)
else:  # pragma: no cover
    def test_roundtrip_random_64_ports():
        rng = random.Random(0)
        for _ in range(200):
            ports = sorted(rng.sample(range(64), rng.randrange(0, 64)))
            bm = ports_to_bitmap(ports, 64)
            for shards in (1, 2, 4, 8):
                assert multicast_ports(bm, 64, shards) == ports
