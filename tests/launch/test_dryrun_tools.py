"""Unit tests for the dry-run analysis tooling (pure functions — the full
lower+compile path is exercised by the sweep logs in experiments/)."""
import jax
import pytest

from repro.launch.analysis import (INPUT_SHAPES, model_flops_per_step,
                                   parse_collective_bytes)
from repro.models import get_config


HLO_SAMPLE = """
  %all-reduce.5 = bf16[1024,512]{1,0} all-reduce(bf16[1024,512]{1,0} %x), replica_groups={}
  %all-gather.2 = f32[64,128]{1,0} all-gather(f32[8,128]{1,0} %y), dimensions={0}
  %rs = f32[8,128]{1,0} reduce-scatter(f32[64,128]{1,0} %z), dimensions={0}
  %a2a = (s32[4,2]{1,0}) all-to-all(s32[4,2]{1,0} %w)
  %cp.1 = u8[16]{0} collective-permute(u8[16]{0} %v)
  %dot.3 = bf16[10,10]{1,0} dot(bf16[10,10]{1,0} %a, bf16[10,10]{1,0} %b)
  %ars = bf16[2,2]{1,0} all-reduce-start(bf16[2,2]{1,0} %q)
"""


def test_parse_collective_bytes_categories():
    r = parse_collective_bytes(HLO_SAMPLE)
    ops = r["per_op_bytes"]
    assert ops["all-reduce"] == 1024 * 512 * 2 + 2 * 2 * 2  # incl. -start
    assert ops["all-gather"] == 64 * 128 * 4
    assert ops["reduce-scatter"] == 8 * 128 * 4
    assert ops["all-to-all"] == 4 * 2 * 4
    assert ops["collective-permute"] == 16
    # all-reduce weighted 2x in the link-byte total
    want = 2 * ops["all-reduce"] + ops["all-gather"] + \
        ops["reduce-scatter"] + ops["all-to-all"] + ops["collective-permute"]
    assert r["total_link_bytes"] == want
    assert r["per_op_count"]["all-reduce"] == 2


def test_parse_ignores_non_collectives():
    r = parse_collective_bytes("%dot = f32[8,8]{1,0} dot(...)\n")
    assert r["total_link_bytes"] == 0


def test_parse_known_dtypes_report_no_unknowns():
    assert parse_collective_bytes(HLO_SAMPLE)["unknown_dtypes"] == {}


def test_parse_unknown_dtype_warns_once_and_is_surfaced():
    """An HLO dtype we have no byte width for must not be silently assumed
    4 B: it is tallied in ``unknown_dtypes`` and warned about once."""
    import warnings

    from repro.launch import analysis

    hlo = "  %ar = f4e2m1fn[64]{0} all-reduce(f4e2m1fn[64]{0} %x)\n" * 3
    analysis._WARNED_DTYPES.discard("f4e2m1fn")  # isolate from other tests
    with pytest.warns(RuntimeWarning, match="f4e2m1fn"):
        r = parse_collective_bytes(hlo)
    assert r["unknown_dtypes"] == {"f4e2m1fn": 3}
    assert r["per_op_bytes"]["all-reduce"] == 3 * 64 * 4  # 4 B fallback
    assert r["per_op_count"]["all-reduce"] == 3
    # warn-once: a second parse of the same dtype stays silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        r2 = parse_collective_bytes(hlo)
    assert r2["unknown_dtypes"] == {"f4e2m1fn": 3}


def test_input_shapes_match_assignment():
    assert INPUT_SHAPES["train_4k"] == dict(kind="train", seq_len=4096,
                                            global_batch=256)
    assert INPUT_SHAPES["prefill_32k"] == dict(kind="prefill", seq_len=32768,
                                               global_batch=32)
    assert INPUT_SHAPES["decode_32k"] == dict(kind="decode", seq_len=32768,
                                              global_batch=128)
    assert INPUT_SHAPES["long_500k"] == dict(kind="decode", seq_len=524288,
                                             global_batch=1)


def test_model_flops_scaling():
    cfg = get_config("llama3.2-1b")
    t = model_flops_per_step(cfg, "train", 4096, 256)
    p = model_flops_per_step(cfg, "prefill", 4096, 256)
    d = model_flops_per_step(cfg, "decode", 4096, 256)
    assert abs(t / p - 3.0) < 1e-9        # 6ND vs 2ND
    assert d == p / 4096                  # one token per sequence
    # MoE: active < total params
    moe = get_config("deepseek-moe-16b")
    assert moe.active_param_count() < moe.param_count()
    ratio = moe.active_param_count() / moe.param_count()
    assert 0.1 < ratio < 0.6              # 6+shared of 64 experts active


def test_param_count_orders_of_magnitude():
    """Sanity: parameter-count estimates land near the published sizes."""
    expect = {
        "llama3.2-1b": (1.0e9, 2.0e9),
        "qwen2-7b": (6e9, 9e9),
        "glm4-9b": (8e9, 12e9),
        "nemotron-4-340b": (3.0e11, 3.8e11),
        "deepseek-moe-16b": (1.4e10, 2.1e10),
        "mamba2-130m": (1.0e8, 2.2e8),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_long500k_eligibility():
    assert not get_config("whisper-large-v3").supports_long_decode()
    for a in ("jamba-v0.1-52b", "mamba2-130m", "llama3.2-1b", "qwen2-vl-2b"):
        assert get_config(a).supports_long_decode()
    # dense archs get the sliding-window variant
    v = get_config("qwen2-7b").long_context_variant(8192)
    assert v.sliding_window == 8192
    # SSM/hybrid run natively — no variant
    assert get_config("mamba2-130m").long_context_variant(8192).sliding_window == 0
