"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward + one train step + decode steps on
CPU, asserting output shapes and finiteness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (decode_step, forward, get_config, init_cache,
                          init_params, list_archs, prepare_cross_cache)
from repro.optim import AdamWConfig
from repro.train import TrainConfig, make_train_step

ARCHS = list_archs()


def _batch(cfg, B=2, S=16):
    key = jax.random.PRNGKey(7)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size, dtype=jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    dt = jnp.dtype(cfg.dtype)
    if cfg.frontend == "audio_stub":
        batch["frames"] = 0.02 * jnp.ones((B, cfg.encoder_seq, cfg.d_model), dt)
    if cfg.frontend == "vision_stub":
        batch["patches"] = 0.02 * jnp.ones((B, cfg.num_patches, cfg.d_model), dt)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_config(arch, "smoke")
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    assert cfg.moe_experts <= 4
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    kw = {}
    if "frames" in batch:
        kw["frames"] = batch["frames"]
    if "patches" in batch:
        kw["extra_embeds"] = batch["patches"]
    logits, aux = forward(params, batch["tokens"], cfg, **kw)
    expect_s = batch["tokens"].shape[1] + (cfg.num_patches
                                           if cfg.frontend == "vision_stub"
                                           else 0)
    assert logits.shape == (2, expect_s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_decreases_loss(arch):
    cfg = get_config(arch, "smoke")
    tc = TrainConfig(model=cfg, optimizer=AdamWConfig(lr=1e-2))
    step = jax.jit(make_train_step(tc))
    params = init_params(cfg, jax.random.PRNGKey(0))
    from repro.optim import init as adamw_init
    opt = adamw_init(params, tc.optimizer)
    batch = _batch(cfg)
    losses = []
    for _ in range(5):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
    # same batch repeated: loss must drop
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_steps(arch):
    cfg = get_config(arch, "smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B = 2
    cache = init_cache(cfg, B, max_len=32)
    if cfg.is_encoder_decoder:
        frames = 0.02 * jnp.ones((B, cfg.encoder_seq, cfg.d_model),
                                 jnp.dtype(cfg.dtype))
        cache["cross"] = prepare_cross_cache(params, frames, cfg)
    tok = jnp.zeros((B, 1), jnp.int32)
    for _ in range(4):
        logits, cache = decode_step(params, cache, tok, cfg)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert int(cache["pos"]) == 4
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_full_configs_match_assignment():
    """The full configs carry the exact published sizes."""
    import repro.configs as C
    cases = {
        "jamba-v0.1-52b": dict(num_layers=32, d_model=4096, num_heads=32,
                               num_kv_heads=8, d_ff=14336, vocab_size=65536,
                               moe_experts=16, moe_top_k=2),
        "nemotron-4-340b": dict(num_layers=96, d_model=18432, num_heads=96,
                                num_kv_heads=8, d_ff=73728,
                                vocab_size=256000, activation="squared_relu"),
        "deepseek-moe-16b": dict(num_layers=28, d_model=2048, num_heads=16,
                                 num_kv_heads=16, moe_d_ff=1408,
                                 vocab_size=102400, moe_experts=64,
                                 moe_top_k=6, moe_shared_experts=2),
        "glm4-9b": dict(num_layers=40, d_model=4096, num_heads=32,
                        num_kv_heads=2, d_ff=13696, vocab_size=151552),
        "qwen2-moe-a2.7b": dict(num_layers=24, d_model=2048, num_heads=16,
                                num_kv_heads=16, moe_d_ff=1408,
                                vocab_size=151936, moe_experts=60,
                                moe_top_k=4, moe_shared_experts=4),
        "qwen2-vl-2b": dict(num_layers=28, d_model=1536, num_heads=12,
                            num_kv_heads=2, d_ff=8960, vocab_size=151936,
                            rope_mode="mrope"),
        "mamba2-130m": dict(num_layers=24, d_model=768, d_ff=0,
                            vocab_size=50280, ssm_state=128),
        "whisper-large-v3": dict(num_layers=32, encoder_layers=32,
                                 d_model=1280, num_heads=20,
                                 num_kv_heads=20, d_ff=5120,
                                 vocab_size=51866),
        "llama3.2-1b": dict(num_layers=16, d_model=2048, num_heads=32,
                            num_kv_heads=8, d_ff=8192, vocab_size=128256),
        "qwen2-7b": dict(num_layers=28, d_model=3584, num_heads=28,
                         num_kv_heads=4, d_ff=18944, vocab_size=152064,
                         qkv_bias=True),
    }
    for arch, expect in cases.items():
        cfg = get_config(arch)
        for k, v in expect.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
        assert cfg.source


def test_jamba_interleave_pattern():
    cfg = get_config("jamba-v0.1-52b")
    kinds = [cfg.layer_kind(i) for i in range(cfg.num_layers)]
    assert kinds.count("attn") == 4      # 1:7 attn:mamba over 32 layers
    assert kinds.count("ssm") == 28
    moes = [cfg.layer_is_moe(i) for i in range(cfg.num_layers)]
    assert sum(moes) == 16               # MoE every other layer


def test_sliding_window_ring_buffer_matches_full_cache():
    """Sliding-window decode with a ring buffer must equal full-cache decode
    with a window mask (same window, same tokens)."""
    cfg = get_config("llama3.2-1b", "smoke").with_(attn_chunk_threshold=1 << 30)
    params = init_params(cfg, jax.random.PRNGKey(0))
    W, T = 8, 20
    swcfg = cfg.with_(sliding_window=W)
    # reference: full cache, sliding-window masking in full_attention happens
    # only for prefill; emulate by decoding with a big cache and comparing
    # the final step against ring-buffer decode.
    ring = init_cache(swcfg, 1, max_len=T)          # C = W ring buffer
    assert ring["layers"][0]["k"].shape[2] == W
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, T), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    lr = None
    for t in range(T):
        lr, ring = decode_step(params, ring, toks[:, t:t + 1], swcfg)
    # reference: bulk forward over the full sequence with window *masking*
    # (the receptive field grows with depth, so the reference must see the
    # whole sequence, not just the last W tokens)
    logits_ref, _ = forward(params, toks, swcfg)
    got = np.asarray(lr[:, -1], np.float32)
    want = np.asarray(logits_ref[:, -1], np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
