"""Mamba-2 SSD correctness: the chunked algorithm must equal the naive
step-by-step state-space recurrence, and decode must equal prefill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import get_config, init_params
from repro.models.mamba2 import (init_mamba2, mamba2_decode_step,
                                 mamba2_forward, mamba2_init_cache,
                                 ssd_chunked)


def ssd_reference(x, dt, A, Bm, Cm):
    """Naive O(S) recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t^T."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    st = np.zeros((b, h, p, n), np.float64)
    ys = []
    x64 = np.asarray(x, np.float64)
    dt64 = np.asarray(dt, np.float64)
    A64 = np.asarray(A, np.float64)
    B64 = np.asarray(Bm, np.float64)
    C64 = np.asarray(Cm, np.float64)
    for t in range(s):
        dec = np.exp(dt64[:, t] * A64[None, :])            # (b, h)
        xdt = x64[:, t] * dt64[:, t][..., None]            # (b, h, p)
        st = st * dec[..., None, None] + \
            np.einsum("bhp,bn->bhpn", xdt, B64[:, t])
        ys.append(np.einsum("bhpn,bn->bhp", st, C64[:, t]))
    return np.stack(ys, axis=1), st


@pytest.mark.parametrize("s,chunk", [(16, 4), (32, 8), (24, 8), (64, 16)])
@pytest.mark.parametrize("seed", [0, 1])
def test_ssd_chunked_matches_recurrence(s, chunk, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    b, h, p, n = 2, 3, 4, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    Cm = jax.random.normal(jax.random.PRNGKey(seed + 10), (b, s, n)) * 0.5
    y, st = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y_ref, st_ref = ssd_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), st_ref, rtol=2e-4, atol=2e-4)


def test_ssd_initial_state_continuation():
    """Splitting a sequence in two with state carry == one full pass."""
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    b, s, h, p, n, chunk = 1, 32, 2, 4, 8, 8
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    Bm = jax.random.normal(ks[3], (b, s, n)) * 0.5
    Cm = jax.random.normal(ks[4], (b, s, n)) * 0.5
    y_full, st_full = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    half = s // 2
    y1, st1 = ssd_chunked(x[:, :half], dt[:, :half], A, Bm[:, :half],
                          Cm[:, :half], chunk)
    y2, st2 = ssd_chunked(x[:, half:], dt[:, half:], A, Bm[:, half:],
                          Cm[:, half:], chunk, init_state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=2e-4, atol=2e-4)


def test_mamba_layer_decode_matches_forward():
    """Stepping the recurrent decode path over a sequence must match the
    chunked full-sequence forward of the same layer."""
    cfg = get_config("mamba2-130m", "smoke").with_(dtype="float32")
    key = jax.random.PRNGKey(0)
    p = init_mamba2(key, cfg, jnp.float32)
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.3
    y_full = mamba2_forward(p, x, cfg)
    cache = mamba2_init_cache(cfg, B)
    outs = []
    for t in range(S):
        y1, cache = mamba2_decode_step(p, x[:, t:t + 1], cache, cfg)
        outs.append(y1)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               rtol=5e-3, atol=5e-3)


def test_full_model_decode_matches_forward_mamba():
    """End-to-end parity for the pure-SSM architecture."""
    from repro.models import decode_step, forward, init_cache
    cfg = get_config("mamba2-130m", "smoke").with_(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    logits_ref, _ = forward(params, toks, cfg)
    cache = init_cache(cfg, B, max_len=S)
    last = None
    for t in range(S):
        last, cache = decode_step(params, cache, toks[:, t:t + 1], cfg)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(logits_ref[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_full_model_decode_matches_forward_hybrid():
    """End-to-end parity for the hybrid (Jamba-style) architecture."""
    from repro.models import decode_step, forward, init_cache
    cfg = get_config("jamba-v0.1-52b", "smoke").with_(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size, dtype=jnp.int32)
    logits_ref, _ = forward(params, toks, cfg)
    cache = init_cache(cfg, B, max_len=S)
    last = None
    for t in range(S):
        last, cache = decode_step(params, cache, toks[:, t:t + 1], cfg)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(logits_ref[:, -1]),
                               rtol=5e-3, atol=5e-3)
