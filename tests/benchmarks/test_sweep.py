"""The parallel sweep runner must match its serial execution exactly and
produce well-formed JSON (the bench-trajectory contract)."""
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _run_sweep(tmp_path, procs: int, name: str) -> dict:
    out = os.path.join(str(tmp_path), f"{name}.json")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["BENCH_FAST"] = "1"
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.sweep", "--suite", "lb",
         "--reps", "2", "--procs", str(procs), "--out", out],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    with open(out) as fh:
        return json.load(fh)


def test_parallel_sweep_matches_serial(tmp_path):
    serial = _run_sweep(tmp_path, procs=0, name="serial")
    parallel = _run_sweep(tmp_path, procs=2, name="parallel")
    assert serial["correct"] and parallel["correct"]
    assert serial["aggregates"] == parallel["aggregates"]
    # every cell identical (order-independent): the pool changes scheduling,
    # never results
    key = lambda c: (c["label"], c["rep"])  # noqa: E731
    strip = lambda c: {k: v for k, v in c.items() if k != "wall_s"}  # noqa: E731
    assert sorted(map(strip, serial["results"]), key=key) == \
        sorted(map(strip, parallel["results"]), key=key)


def test_sweep_document_shape(tmp_path):
    doc = _run_sweep(tmp_path, procs=2, name="shape")
    assert doc["suite"] == "lb" and doc["cells"] == 6
    assert set(doc["aggregates"]) == {"canary/lb=ecmp", "canary/lb=adaptive",
                                      "canary/lb=per_packet"}
    for cell in doc["results"]:
        assert cell["events"] > 0 and cell["goodput_gbps"] > 0
    assert doc["wall_s"] > 0 and doc["cpu_s"] > 0
