"""Schedule compiler + JAX executor tests.

The headline acceptance check lives here: replaying one set of inputs in
fixed-point mode across trees recorded under **different seeds/timeouts**
(i.e. different dynamic tree shapes, including the host-based fallback
shape) yields **bit-identical int32 results**, which dequantize to the float
reference allreduce within quantization tolerance.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.canary import Algo, AllreduceJob, Simulator, scaled_config
from repro.core.trace import (compile_app, compile_block, fixed_point_replay,
                              reference_allreduce, replay_app, replay_block,
                              schedule_report)

P = 10          # participants
BLOCK_BYTES = 1024
N_BLOCKS = 4
D = 32          # elements per block used for replay


def _traced_run(algo=Algo.CANARY, *, noise=None, **cfg_kw):
    base = dict(seed=3, timeout_ns=200.0)
    base.update(cfg_kw)
    cfg = scaled_config(4, trace=True, **base)
    jobs = [AllreduceJob(app=0, participants=list(range(P)),
                         data_bytes=N_BLOCKS * BLOCK_BYTES)]
    sim = Simulator(cfg, jobs, algo=algo, noise_hosts=noise)
    assert sim.run().correct
    return sim


# Three worlds that provably form different trees: aggressive timeouts with
# sender noise, a hopeless timeout that ends in the §3.3 host-based fallback,
# and a mid-range window (verified distinct by test_tree_shapes_differ).
VARIANTS = [
    dict(seed=3, timeout_ns=50.0, noise_prob=0.2),
    dict(seed=11, timeout_ns=1e6, retx_timeout_ns=2e5),
    dict(seed=29, timeout_ns=500.0, noise_prob=0.05),
]


def _shape_signature(schedules):
    return tuple((s.depth,
                  tuple(sorted(len(st.srcs) for r in s.reduce_rounds
                               for st in r)))
                 for s in schedules)


@pytest.fixture(scope="module")
def variant_schedules():
    out = []
    for kw in VARIANTS:
        sim = _traced_run(noise=list(range(P, 16)), **kw)
        out.append(compile_app(sim.trace, 0))
    return out


@pytest.fixture(scope="module")
def inputs():
    return jax.random.normal(jax.random.PRNGKey(0),
                             (P, N_BLOCKS, D)) * 3.0


# ------------------------------------------------------------- compile shape
def test_compile_round_invariants(variant_schedules):
    """Rounds are a valid dataflow order: every source buffer is a leaf or
    was produced in a strictly earlier round; destinations are unique."""
    for schedules in variant_schedules:
        assert len(schedules) == N_BLOCKS
        for s in schedules:
            ready = set(s.leaf_host)
            for rnd in s.reduce_rounds:
                dsts = [step.dst for step in rnd]
                assert len(dsts) == len(set(dsts))
                for step in rnd:
                    assert all(src in ready for src in step.srcs)
                ready.update(dsts)
            assert s.root in ready
            assert sorted(set(s.leaf_host.values())) == s.hosts


def test_tree_shapes_differ(variant_schedules):
    sigs = {_shape_signature(s) for s in variant_schedules}
    assert len(sigs) >= 2, "variants were supposed to produce distinct trees"


def test_schedule_report(variant_schedules):
    rep = schedule_report(variant_schedules[0], BLOCK_BYTES)
    assert rep["blocks"] == N_BLOCKS
    assert rep["depth_max"] >= 1
    assert rep["bytes_moved"] == rep["messages"] * BLOCK_BYTES


# ------------------------------------------------------------- float replay
def test_float_replay_matches_reference(variant_schedules, inputs):
    for schedules in variant_schedules:
        out = replay_app(schedules, inputs)
        ref = reference_allreduce(inputs.reshape(P, -1)).reshape(inputs.shape)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-4)


def test_single_block_replay(variant_schedules, inputs):
    s = variant_schedules[0][0]
    out = replay_block(s, inputs[:, 0])
    want = jnp.sum(inputs[:, 0], axis=0)
    np.testing.assert_allclose(np.asarray(out),
                               np.broadcast_to(np.asarray(want), (P, D)),
                               rtol=1e-5, atol=1e-4)


def test_replay_rejects_wrong_shapes(variant_schedules, inputs):
    with pytest.raises(ValueError):
        replay_block(variant_schedules[0][0], inputs[:P - 1, 0])
    with pytest.raises(ValueError):
        replay_app(variant_schedules[0][:2], inputs)


# ------------------------------------------- fixed-point determinism (§6)
def test_fixed_point_bit_identical_across_tree_shapes(variant_schedules,
                                                      inputs):
    """The acceptance claim: identical int32 results no matter which dynamic
    tree the congested fabric produced, and floats within quantization
    tolerance of the reference."""
    bits = 20
    q_results = []
    for schedules in variant_schedules:
        out, q = fixed_point_replay(schedules, inputs, bits=bits)
        q_results.append(np.asarray(q))
        assert q.dtype == jnp.int32
        ref = reference_allreduce(inputs.reshape(P, -1)).reshape(inputs.shape)
        # each of P quantized summands carries <= 0.5/scale rounding error
        from repro.kernels.ops import fixed_point_scale
        gmax = float(jnp.max(jnp.abs(inputs)))
        scale = fixed_point_scale(gmax, bits=bits, world=P)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=(P + 1) * 0.5 / scale)
    for q in q_results[1:]:
        np.testing.assert_array_equal(q_results[0], q)


def test_int32_replay_is_exact_sum(variant_schedules):
    """Integer accumulation over the tree equals the direct sum exactly."""
    q = jax.random.randint(jax.random.PRNGKey(7), (P, N_BLOCKS, D),
                           -1_000_000, 1_000_000, dtype=jnp.int32)
    for schedules in variant_schedules:
        out = replay_app(schedules, q)
        assert out.dtype == jnp.int32
        want = jnp.sum(q, axis=0)
        np.testing.assert_array_equal(
            np.asarray(out), np.broadcast_to(np.asarray(want), q.shape))


def test_static_tree_replay(inputs):
    sim = _traced_run(algo=Algo.STATIC_TREE)
    schedules = compile_app(sim.trace, 0)
    out = replay_app(schedules, inputs)
    ref = reference_allreduce(inputs.reshape(P, -1)).reshape(inputs.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)


def test_compile_block_direct():
    sim = _traced_run()
    tree = sim.trace.block_tree(0, 0)
    s = compile_block(tree)
    assert s.depth == tree.depth()
    assert s.timeout_flushes == tree.timeout_flushes()
