"""TraceRecorder tests: observation-only contract + conservation invariant.

Two pillars:

* **Traced golden replay** — every golden-replay scenario re-run with
  ``SimConfig.trace=True`` must reproduce its pinned ``SimResult``
  bit-for-bit: recording observes the run, it never perturbs it.
* **Conservation** — for every completed block, the recorded tree proves each
  participant's contribution was aggregated exactly once (no loss, no
  double-count), across CANARY/STATIC_TREE, fat_tree/three_tier, drops,
  collisions, stragglers, retransmission generations and switch failures.
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "core"))

from collections import Counter

from golden_cases import CASES, _cfg, _jobs, load_goldens, result_to_jsonable
from repro.core.canary import (Algo, AllreduceJob, SimConfig, Simulator,
                               scaled_config, three_tier_config)
from repro.core.trace import HOST_SEND, LEADER, STATIC_ROOT

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False


# ------------------------------------------------------ traced golden replay
@pytest.fixture(scope="module")
def goldens():
    return load_goldens()


@pytest.mark.parametrize("name", sorted(CASES))
def test_goldens_unchanged_with_tracing(name, goldens):
    """Recording is observation-only: the traced run's SimResult is
    bit-identical to the untraced golden."""
    cfg_kw, jobs_spec, algo, n_trees, noise = CASES[name]
    cfg = _cfg(**cfg_kw)
    cfg.trace = True
    sim = Simulator(cfg, _jobs(jobs_spec), algo=algo, n_trees=n_trees,
                    noise_hosts=noise)
    got = result_to_jsonable(sim.run())
    want = goldens[name]
    for field in sorted(want):
        assert got[field] == want[field], f"{name}: field {field!r} diverged"
    assert got == want
    if algo != Algo.RING:  # host-based runs record nothing
        assert len(sim.trace.nodes) > 0


# ------------------------------------------------------------- conservation
def _run_traced(cfg: SimConfig, jobs, algo, n_trees=1, noise=None):
    cfg.trace = True
    sim = Simulator(cfg, jobs, algo=algo, n_trees=n_trees, noise_hosts=noise)
    result = sim.run()
    assert result.correct, "simulation itself must be correct"
    return sim


def _assert_conservation(sim, expect_blocks=None):
    keys = sim.trace.block_keys()
    if expect_blocks is not None:
        assert len(keys) == expect_blocks, (len(keys), expect_blocks)
    assert keys, "no completed blocks recorded"
    for app, block in keys:
        tree = sim.trace.block_tree(app, block)
        tree.check_conservation()
        # leaves are exactly the participants, once each
        leaf_hosts = Counter(n.where for n in tree.leaves())
        assert leaf_hosts == Counter({h: 1 for h in tree.participants})


FABRICS = {
    "fat_tree": lambda **kw: scaled_config(4, **kw),
    "three_tier": lambda **kw: three_tier_config(**kw),
}


@pytest.mark.parametrize("fabric", sorted(FABRICS))
@pytest.mark.parametrize("algo", [Algo.CANARY, Algo.STATIC_TREE])
def test_conservation_basic(fabric, algo):
    cfg = FABRICS[fabric](seed=7, timeout_ns=300.0)
    jobs = [AllreduceJob(app=0, participants=list(range(0, 16, 2)),
                         data_bytes=16384)]
    sim = _run_traced(cfg, jobs, algo)
    _assert_conservation(sim, expect_blocks=16)
    # every participant received the broadcast result
    assert sim.trace.delivered[(0, 0)] == set(range(0, 16, 2))


@pytest.mark.parametrize("fabric", sorted(FABRICS))
def test_conservation_under_drops(fabric):
    """Loss recovery (§3.3) re-issues contributions under fresh generations;
    the completed generation still aggregates each host exactly once."""
    cfg = FABRICS[fabric](seed=5, drop_prob=0.01, retx_timeout_ns=5e4)
    jobs = [AllreduceJob(app=0, participants=list(range(10)),
                         data_bytes=16384)]
    sim = _run_traced(cfg, jobs, Algo.CANARY)
    _assert_conservation(sim)


# Failed switches must have path redundancy the LB can route around: a spine
# on the 4-leaf fat tree (id 5), a core on the default three-tier (id 17 —
# 8 leaves + 8 aggs, then cores). Killing a leaf would strand its hosts; an
# agg can pin capped-generation flow hashes onto the dead path.
@pytest.mark.parametrize("fabric,failed_switch", [("fat_tree", 5),
                                                  ("three_tier", 17)])
def test_conservation_under_switch_failure(fabric, failed_switch):
    cfg = FABRICS[fabric](seed=3, switch_fail_ns=2000.0,
                          failed_switch=failed_switch, retx_timeout_ns=5e4,
                          max_events=20_000_000)
    jobs = [AllreduceJob(app=0, participants=list(range(10)),
                         data_bytes=32768)]
    sim = _run_traced(cfg, jobs, Algo.CANARY)
    _assert_conservation(sim)
    assert sim.trace.timeout_flushes + sim.trace.complete_flushes > 0


def test_conservation_with_collisions_and_restoration():
    """table_size=1 forces descriptor collisions: bypassed contributions
    merge at the leader and restorations fan the result back out."""
    cfg = scaled_config(4, seed=11, table_size=1)
    jobs = [AllreduceJob(app=0, participants=list(range(8)),
                         data_bytes=16384)]
    sim = _run_traced(cfg, jobs, Algo.CANARY)
    _assert_conservation(sim)
    assert sim.trace.collisions > 0
    assert sim.trace.restores, "collisions must trigger restorations"


def test_conservation_under_congestion_noise():
    cfg = scaled_config(4, seed=13, noise_prob=0.05, timeout_ns=200.0)
    jobs = [AllreduceJob(app=0, participants=list(range(8)),
                         data_bytes=32768)]
    sim = _run_traced(cfg, jobs, Algo.CANARY, noise=list(range(8, 16)))
    _assert_conservation(sim)


def test_conservation_static_four_trees_three_tier():
    cfg = three_tier_config(seed=17)
    jobs = [AllreduceJob(app=0, participants=list(range(12)),
                         data_bytes=16384)]
    sim = _run_traced(cfg, jobs, Algo.STATIC_TREE, n_trees=4)
    _assert_conservation(sim)
    roots = {sim.trace.block_tree(a, b).nodes[
        sim.trace.block_tree(a, b).root].kind
        for a, b in sim.trace.block_keys()}
    assert roots == {STATIC_ROOT}


def test_conservation_multiapp_and_mixed_collectives():
    cfg = scaled_config(4, seed=2, table_size=8192)
    jobs = [AllreduceJob(app=0, participants=[0, 1, 2, 3], data_bytes=16384),
            AllreduceJob(app=1, participants=[4, 5, 6, 7], data_bytes=16384,
                         collective="reduce", root=4),
            AllreduceJob(app=2, participants=[8, 9, 10, 11], data_bytes=16384,
                         collective="broadcast", root=8),
            AllreduceJob(app=3, participants=[12, 13, 14, 15], data_bytes=0,
                         collective="barrier")]
    sim = _run_traced(cfg, jobs, Algo.CANARY)
    _assert_conservation(sim)
    apps = {a for a, _ in sim.trace.block_keys()}
    assert apps == {0, 1, 2, 3}


def test_conservation_with_fallback_generations():
    """A hopeless timeout drives generations to the host-based fallback
    (§3.3): the completed tree is leader-direct, still exactly-once."""
    cfg = scaled_config(4, seed=11, timeout_ns=1e6, retx_timeout_ns=2e5)
    jobs = [AllreduceJob(app=0, participants=list(range(10)),
                         data_bytes=4096)]
    sim = _run_traced(cfg, jobs, Algo.CANARY)
    _assert_conservation(sim)
    gens = [sim.trace.block_tree(a, b).gen for a, b in sim.trace.block_keys()]
    assert max(gens) > 0, "expected retransmission generations"


if HAVE_HYP:
    @given(seed=st.integers(0, 1000),
           timeout_ns=st.sampled_from([50.0, 300.0, 1000.0, 5000.0]))
    @settings(max_examples=10, deadline=None)
    def test_conservation_property(seed, timeout_ns):
        cfg = scaled_config(4, seed=seed, timeout_ns=timeout_ns,
                            noise_prob=0.05)
        jobs = [AllreduceJob(app=0, participants=list(range(8)),
                             data_bytes=8192)]
        sim = _run_traced(cfg, jobs, Algo.CANARY,
                          noise=list(range(8, 12)))
        _assert_conservation(sim, expect_blocks=8)


# -------------------------------------------------------------- recorder API
def test_recorder_counters_match_simresult():
    cfg = scaled_config(4, seed=11, table_size=1, trace=True)
    jobs = [AllreduceJob(app=0, participants=list(range(8)),
                         data_bytes=16384)]
    sim = Simulator(cfg, jobs, algo=Algo.CANARY)
    result = sim.run()
    assert sim.trace.collisions == result.collisions
    assert sim.trace.stragglers == result.stragglers


def test_tree_structure_and_summary():
    cfg = scaled_config(4, seed=3, timeout_ns=200.0, trace=True)
    jobs = [AllreduceJob(app=0, participants=list(range(8)),
                         data_bytes=8192)]
    sim = Simulator(cfg, jobs, algo=Algo.CANARY)
    assert sim.run().correct
    tree = sim.trace.block_tree(0, 0)
    root = tree.nodes[tree.root]
    assert root.kind == LEADER
    assert tree.depth() >= 1
    assert all(n.kind == HOST_SEND for n in tree.leaves())
    assert "depth=" in tree.summary()
    deepest = sim.trace.deepest_tree()
    assert deepest is not None
    assert deepest.depth() >= tree.depth()
    assert "completed blocks" in sim.trace.summary()


def test_ring_records_nothing():
    cfg = scaled_config(4, seed=0, trace=True)
    jobs = [AllreduceJob(app=0, participants=list(range(6)),
                         data_bytes=8192)]
    sim = Simulator(cfg, jobs, algo=Algo.RING)
    assert sim.run().correct
    assert sim.trace.block_keys() == []
    with pytest.raises(KeyError):
        sim.trace.block_tree(0, 0)


def test_untraced_run_has_no_recorder():
    cfg = scaled_config(4, seed=0)
    jobs = [AllreduceJob(app=0, participants=list(range(4)),
                         data_bytes=4096)]
    sim = Simulator(cfg, jobs, algo=Algo.CANARY)
    assert sim.trace is None
    assert sim.run().correct
